//! Integration tests: whole-pipeline flows across modules.

use pars3::coordinator::{Backend, Config, Coordinator, Service};
use pars3::kernel::serial_sss::sss_spmv;
use pars3::mpisim::CostModel;
use pars3::report;
use pars3::solver::mrs::MrsOptions;
use pars3::sparse::{convert, gen, mm_io, skew, Symmetry};
use pars3::util::SmallRng;

fn small_cfg() -> Config {
    Config { scale: 0.08, ..Config::default() }
}

#[test]
fn full_pipeline_on_suite_smoke() {
    // generate -> RCM -> split -> conflict map -> pars3 == serial
    let suite = report::prepared_suite(&small_cfg()).unwrap();
    assert_eq!(suite.len(), 6);
    let mut coord = Coordinator::new(small_cfg());
    for (m, prep) in &suite {
        let x: Vec<f64> = (0..prep.n).map(|i| ((i * 7) % 13) as f64 * 0.1).collect();
        let y0 = coord.spmv(prep, &x, Backend::Serial).unwrap();
        let y1 = coord.spmv(prep, &x, Backend::Pars3 { p: 8 }).unwrap();
        let err = y0.iter().zip(&y1).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        assert!(err < 1e-9, "{}: err={err}", m.name);
        // RCM should never *increase* the bandwidth on scrambled inputs
        assert!(prep.reordered_bw <= prep.bw_before, "{}", m.name);
    }
}

#[test]
fn table1_orderings_match_paper() {
    // the analogue suite must preserve the paper's relative orderings,
    // which drive the Figure 9 speedup ranking
    let suite = report::prepared_suite(&small_cfg()).unwrap();
    let get = |n: &str| suite.iter().find(|(m, _)| m.name == n).unwrap();
    let (_, af) = get("af_5_k101_like");
    let (_, serena) = get("Serena_like");
    let (_, audikw) = get("audikw_1_like");
    // af has the smallest relative RCM bandwidth...
    for (m, p) in &suite {
        if m.name != "af_5_k101_like" {
            let af_rel = af.reordered_bw as f64 / af.n as f64;
            let p_rel = p.reordered_bw as f64 / p.n as f64;
            assert!(
                af_rel <= p_rel * 1.05,
                "af bw/n should be smallest, vs {}",
                m.name
            );
        }
    }
    // ...and Serena/audikw the largest relative bandwidths (paper Table 1)
    let rel = |p: &pars3::coordinator::Prepared| p.reordered_bw as f64 / p.n as f64;
    let mut rels: Vec<f64> = suite.iter().map(|(_, p)| rel(p)).collect();
    rels.sort_by(|a, b| a.partial_cmp(b).unwrap());
    assert!(rel(serena) >= rels[3], "Serena should be among the widest");
    assert!(rel(audikw) >= rels[2], "audikw should be among the widest");
}

#[test]
fn mrs_through_all_native_backends_agrees() {
    let coo = gen::small_test_matrix(400, 5, 2.5);
    let mut coord = Coordinator::new(Config::default());
    let prep = coord.prepare("it", &coo).unwrap();
    let mut rng = SmallRng::seed_from_u64(3);
    let b: Vec<f64> = (0..prep.n).map(|_| rng.gen_normal()).collect();
    let opts = MrsOptions { alpha: 2.5, max_iters: 400, tol: 1e-9 };
    let r_serial = coord.solve(&prep, &b, &opts, Backend::Serial).unwrap();
    assert!(r_serial.converged);
    for p in [2, 5, 16] {
        let r = coord.solve(&prep, &b, &opts, Backend::Pars3 { p }).unwrap();
        assert!(r.converged, "p={p}");
        let err = r_serial.x.iter().zip(&r.x).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        assert!(err < 1e-6, "p={p} err={err}");
    }
}

#[test]
fn matrix_market_roundtrip_through_pipeline() {
    let coo = gen::small_test_matrix(150, 9, 1.0);
    let path = std::env::temp_dir().join("pars3_integration.mtx");
    mm_io::write_matrix_market(&path, &coo).unwrap();
    let (loaded, _) = mm_io::read_matrix_market(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let coord = Coordinator::new(Config::default());
    let p0 = coord.prepare("orig", &coo).unwrap();
    let p1 = coord.prepare("loaded", &loaded).unwrap();
    assert_eq!(p0.reordered_bw, p1.reordered_bw);
    assert_eq!(p0.nnz_lower, p1.nnz_lower);
}

#[test]
fn reordering_preserves_spmv_semantics() {
    // y_orig = P^T * (A_perm * (P * x)) must equal A * x
    let coo = gen::small_test_matrix(200, 11, 1.5);
    let coord = Coordinator::new(Config::default());
    let prep = coord.prepare("perm", &coo).unwrap();
    let x: Vec<f64> = (0..200).map(|i| (i as f64 * 0.05).cos()).collect();
    // native multiply in original order
    let csr = convert::coo_to_csr(&coo);
    let mut y_orig = vec![0.0; 200];
    pars3::kernel::csr_spmv::csr_spmv(&csr, &x, &mut y_orig);
    // multiply in RCM order, then un-permute
    let mut xp = vec![0.0; 200];
    for (old, &new) in prep.perm.iter().enumerate() {
        xp[new as usize] = x[old];
    }
    let mut yp = vec![0.0; 200];
    sss_spmv(&prep.sss, &xp, &mut yp);
    for (old, &new) in prep.perm.iter().enumerate() {
        assert!((yp[new as usize] - y_orig[old]).abs() < 1e-10, "row {old}");
    }
}

#[test]
fn rcm_bicriteria_matches_rcm_numerics_through_every_kernel() {
    // the bi-criteria start nodes change the ordering, never the
    // operator: for every registered kernel, multiplying in either
    // ordering and mapping back to the original index space must give
    // the same vector as the natural-order CSR reference
    use pars3::kernel::registry::{build_from_sss, reorder_to_sss, KernelConfig};
    use pars3::kernel::KERNEL_NAMES;
    use pars3::graph::reorder::ReorderPolicy;
    let n = 160;
    let coo = gen::small_test_matrix(n, 21, 2.0);
    let x: Vec<f64> = (0..n).map(|i| ((i * 13) % 17) as f64 * 0.2 - 1.5).collect();
    let csr = convert::coo_to_csr(&coo);
    let mut want = vec![0.0; n];
    pars3::kernel::csr_spmv::csr_spmv(&csr, &x, &mut want);
    for policy in [ReorderPolicy::Rcm, ReorderPolicy::RcmBiCriteria] {
        let (perm, sss, report) = reorder_to_sss(&coo, policy, 0.0).unwrap();
        assert_eq!(report.strategy, policy.name());
        let sss = std::sync::Arc::new(sss);
        let mut xp = vec![0.0; n];
        for (old, &new) in perm.iter().enumerate() {
            xp[new as usize] = x[old];
        }
        for &name in KERNEL_NAMES {
            let mut k =
                build_from_sss(name, sss.clone(), &KernelConfig::with_threads(4)).unwrap();
            let mut yp = vec![0.0; n];
            k.apply(&xp, &mut yp);
            for (old, &new) in perm.iter().enumerate() {
                assert!(
                    (yp[new as usize] - want[old]).abs() < 1e-9,
                    "{policy:?}/{name} row {old}: {} vs {}",
                    yp[new as usize],
                    want[old]
                );
            }
        }
    }
}

#[test]
fn service_handles_pipelined_workload() {
    let svc = Service::start(small_cfg());
    let client = svc.client();
    let coo = gen::small_test_matrix(100, 2, 2.0);
    let h = client.prepare("a", coo).wait().unwrap();
    // repeated multiplies against the same preprocessed matrix (the
    // amortization story of §4) — all five submitted before any wait
    let tickets: Vec<_> = (0..5)
        .map(|k| {
            let x: Vec<f64> = (0..100).map(|i| ((i + k) as f64 * 0.2).sin()).collect();
            client.spmv(&h, x, Backend::Pars3 { p: 4 })
        })
        .collect();
    let norms: Vec<f64> = tickets
        .into_iter()
        .map(|t| t.wait().unwrap().iter().map(|v| v * v).sum::<f64>().sqrt())
        .collect();
    assert_eq!(norms.len(), 5);
    // five pipelined tickets, one kernel build on the owning shard
    let stats = client.cache_stats(h.shard()).wait().unwrap();
    assert_eq!(stats.built, 1, "pipelined tickets must share one cached kernel");
    svc.shutdown();
}

#[test]
fn clients_pipeline_mixed_tickets_across_shards() {
    // >= 4 client threads pipelining mixed spmv/solve tickets against
    // two matrices living on different shards; every result is checked
    // against a direct (single-owner) Coordinator on the same config
    let cfg = Config { shards: 2, ..small_cfg() };
    let coo_a = gen::small_test_matrix(110, 3, 2.0);
    let coo_b = gen::small_test_matrix(90, 4, 2.0);
    let opts = MrsOptions { alpha: 2.0, max_iters: 300, tol: 1e-8 };

    // reference answers, computed outside the service
    let mut coord = Coordinator::new(cfg.clone());
    let prep_a = coord.prepare("a", &coo_a).unwrap();
    let prep_b = coord.prepare("b", &coo_b).unwrap();
    let xs_a: Vec<Vec<f64>> = (0..4)
        .map(|t| (0..110).map(|i| ((i * (t + 2)) % 13) as f64 * 0.1 - 0.6).collect())
        .collect();
    let bs_b: Vec<Vec<f64>> = (0..4)
        .map(|t| (0..90).map(|i| ((i + 7 * t) % 5) as f64 - 2.0).collect())
        .collect();
    let want_y: Vec<Vec<f64>> = xs_a
        .iter()
        .map(|x| coord.spmv(&prep_a, x, Backend::Pars3 { p: 4 }).unwrap())
        .collect();
    let want_solve: Vec<Vec<f64>> = bs_b
        .iter()
        .map(|b| coord.solve(&prep_b, b, &opts, Backend::Serial).unwrap().x)
        .collect();

    let svc = Service::start(cfg);
    let client = svc.client();
    let ha = client.prepare("a", coo_a).wait().unwrap();
    let hb = client.prepare("b", coo_b).wait().unwrap();
    assert_ne!(ha.shard(), hb.shard(), "round-robin must spread the two matrices");

    std::thread::scope(|s| {
        for t in 0..4 {
            let client = client.clone();
            let (xs_a, bs_b) = (&xs_a, &bs_b);
            let (want_y, want_solve) = (&want_y, &want_solve);
            let opts = opts.clone();
            s.spawn(move || {
                // pipeline a mixed burst: spmv on shard A and solve on
                // shard B are in flight simultaneously
                let ty = client.spmv(&ha, xs_a[t].clone(), Backend::Pars3 { p: 4 });
                let ts = client.solve(&hb, bs_b[t].clone(), opts, Backend::Serial);
                // collect in reverse submission order: the spmv ticket
                // must resolve although nobody waited on it first
                let solved = ts.wait().unwrap();
                let y = ty.wait().unwrap();
                for (got, want) in y.iter().zip(&want_y[t]) {
                    assert!((got - want).abs() < 1e-10, "thread {t} spmv");
                }
                assert!(solved.converged);
                for (got, want) in solved.x.iter().zip(&want_solve[t]) {
                    assert!((got - want).abs() < 1e-10, "thread {t} solve");
                }
            });
        }
    });

    // each shard built its kernel once, reused by all four threads
    for shard in 0..svc.num_shards() {
        let stats = client.cache_stats(shard).wait().unwrap();
        assert_eq!(stats.built, 1, "shard {shard} must reuse its cached kernel");
    }
    svc.shutdown();
}

#[test]
fn cost_model_reproduces_paper_orderings() {
    // Figure 9's qualitative claims on the analogue suite
    let suite = report::prepared_suite(&small_cfg()).unwrap();
    let model = CostModel::default();
    let ranks = [1usize, 4, 16, 64];
    let f = report::fig9(&suite, &ranks, &model);
    let series = |n: &str| &f.series.iter().find(|(m, _)| m == n).unwrap().1;
    let af = series("af_5_k101_like");
    // (1) speedup grows with P for the well-banded matrix
    assert!(af[1] > af[0] && af[2] > af[1], "{af:?}");
    // (2) below ideal
    for (name, sp) in &f.series {
        for (s, &p) in sp.iter().zip(&ranks) {
            assert!(*s <= p as f64 + 1e-9, "{name} at P={p}: {s}");
        }
    }
    // (3) controlled experiment for the paper's driver: at equal NNZ,
    //     the smaller-bandwidth matrix scales better (Table 1 -> Fig 9
    //     correlation). Narrow band vs same pattern + long-range edges.
    let mut rng = pars3::util::SmallRng::seed_from_u64(5);
    let n = 3000;
    let narrow_edges = gen::random_banded_pattern(n, 5, 0.5, &mut rng);
    let mut wide_edges = narrow_edges.clone();
    gen::add_long_range(&mut wide_edges, n, 0.15, &mut rng);
    let coord = Coordinator::new(Config::default());
    let prep_n = coord
        .prepare("narrow", &skew::coo_from_pattern(n, &narrow_edges, 2.0, &mut rng))
        .unwrap();
    let prep_w = coord
        .prepare("wide", &skew::coo_from_pattern(n, &wide_edges, 2.0, &mut rng))
        .unwrap();
    assert!(prep_n.reordered_bw < prep_w.reordered_bw);
    let sp = |prep: &pars3::coordinator::Prepared| {
        let cm = prep.conflicts(32);
        let serial = model.serial_time(prep.n, prep.nnz_lower);
        model.speedup(serial, model.pars3_makespan(&cm, &prep.split))
    };
    assert!(
        sp(&prep_n) >= sp(&prep_w) * 0.95,
        "narrow {} vs wide {}",
        sp(&prep_n),
        sp(&prep_w)
    );
}

#[test]
fn coloring_baseline_loses_at_scale() {
    // §4.1: PARS3 over-performs the synchronization-phase approach
    let suite = report::prepared_suite(&small_cfg()).unwrap();
    let model = CostModel::default();
    for (m, prep) in &suite {
        let coloring = pars3::graph::coloring::color_rows(&prep.sss);
        let cm = prep.conflicts(32);
        let t_pars3 = model.pars3_makespan(&cm, &prep.split);
        let t_color = model.coloring_makespan(&prep.sss, &coloring, 32);
        assert!(
            t_pars3 < t_color,
            "{}: pars3 {t_pars3:.3e} vs coloring {t_color:.3e}",
            m.name
        );
    }
}

#[test]
fn pinning_one_axis_restricts_only_that_axis_end_to_end() {
    // pin the backend through config: planning must keep scoring the
    // reorder and format axes, the pinned axis shows exactly one
    // candidate, and both the direct-Coordinator and Service paths
    // report the same plan shape and numerics
    use pars3::coordinator::BackendPolicy;
    let cfg = Config { backend: BackendPolicy::Serial, ..Config::default() };
    let mut coord = Coordinator::new(cfg.clone());
    let coo = gen::small_test_matrix(130, 12, 2.0);
    let prep = coord.prepare("pin", &coo).unwrap();
    assert_eq!(prep.choice.backend, Backend::Serial);
    let backend_axis = prep.plan.axis("backend").unwrap();
    assert!(backend_axis.pinned, "configured backend must pin the axis");
    assert_eq!(backend_axis.candidates.len(), 1);
    for name in ["reorder", "format"] {
        let ax = prep.plan.axis(name).unwrap();
        assert!(!ax.pinned, "{name} must stay planned");
        assert!(ax.candidates.len() >= 2, "{name} must list scored alternatives");
        assert_eq!(ax.candidates.iter().filter(|c| c.chosen).count(), 1, "{name}");
    }

    // the same shape is visible through the sharded service
    let svc = Service::start(cfg);
    let client = svc.client();
    let h = client.prepare("pin", coo).wait().unwrap();
    let info = client.describe(&h).wait().unwrap();
    assert_eq!(info.choice.backend, Backend::Serial);
    assert!(info.plan.axis("backend").unwrap().pinned);
    assert!(!info.plan.axis("format").unwrap().pinned);

    // executing on the planned triple matches an explicit request
    let x: Vec<f64> = (0..130).map(|i| (i as f64 * 0.31).sin()).collect();
    let via_plan = client.spmv(&h, x.clone(), info.choice.backend).wait().unwrap();
    let explicit = coord.spmv(&prep, &x, Backend::Serial).unwrap();
    for (r, (a, b)) in via_plan.iter().zip(&explicit).enumerate() {
        assert!((a - b).abs() <= 1e-12, "row {r}: {a} vs {b}");
    }
    svc.shutdown();
}

#[test]
fn skew_part_preconditioning_flow() {
    // general matrix -> skew projection -> shifted system -> solve
    let coo = gen::small_test_matrix(120, 31, 0.0);
    let mut csr = convert::coo_to_csr(&coo);
    // perturb to make it non-skew (general)
    for v in csr.vals.iter_mut().take(20) {
        *v += 0.3;
    }
    let s = skew::skew_part(&csr);
    let mut shifted = s.clone();
    for i in 0..shifted.n as u32 {
        shifted.push(i, i, 2.0);
    }
    let sss = convert::coo_to_sss(&shifted, Symmetry::Skew).unwrap();
    let mut k = pars3::kernel::serial_sss::SerialSss::new(sss);
    let b = vec![1.0; 120];
    let r = pars3::solver::mrs::mrs_solve(
        &mut k,
        &b,
        &MrsOptions { alpha: 2.0, max_iters: 500, tol: 1e-8 },
    );
    assert!(r.converged);
}

#[test]
fn remote_client_matches_local_pipeline_over_tcp_and_uds() {
    // the wire is a transport, not a different engine: for every
    // registry backend, a RemoteClient over TCP and over UDS must
    // return what a direct Coordinator returns on the same matrix —
    // with the whole burst submitted before the first wait, same as
    // the in-process pipelining contract.
    use pars3::coordinator::{ClientApi, Pars3Error};
    use pars3::kernel::VecBatch;
    use pars3::net::{Listen, RemoteClient, Server};

    let n = 160;
    let alpha = 2.0;
    let coo = gen::small_test_matrix(n, 9, alpha);
    let mut coord = Coordinator::new(Config::default());
    let prep = coord.prepare("ref", &coo).unwrap();
    let p = 4;
    let backends = [
        Backend::Serial,
        Backend::Csr,
        Backend::Dgbmv,
        Backend::Coloring { p },
        Backend::Race { p },
        Backend::Pars3 { p },
    ];
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.2).sin()).collect();
    let xs = VecBatch::from_fn(n, 3, |i, c| ((i * 3 + c) as f64 * 0.05).cos());
    let opts = MrsOptions { alpha, max_iters: 200, tol: 1e-8 };

    let dir = std::env::temp_dir().join(format!("pars3-it-net-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let listens =
        [Listen::Tcp("127.0.0.1:0".to_string()), Listen::Uds(dir.join("loopback.sock"))];

    for listen in &listens {
        let server =
            Server::bind(listen, Config { shards: 2, ..Config::default() }).unwrap();
        let client = RemoteClient::connect(server.local_addr()).unwrap();
        let h = client.prepare("m", coo.clone()).wait().unwrap();

        // backend sweep, pipelined: every request is on the wire before
        // the first wait
        let tickets: Vec<_> =
            backends.iter().map(|&b| client.spmv(&h, x.clone(), b)).collect();
        assert_eq!(tickets.len(), backends.len(), "all submitted before any wait");
        for (&backend, t) in backends.iter().zip(tickets) {
            let got = t.wait().unwrap();
            let want = coord.spmv(&prep, &x, backend).unwrap();
            let diff =
                got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
            assert!(diff <= 1e-12, "{listen}: {backend:?} diverged by {diff:.3e}");
        }

        // fused batch and solve agree too (raw-LE f64 batches both ways)
        let got = client.spmv_batch(&h, xs.clone(), Backend::Pars3 { p }).wait().unwrap();
        let want = coord.spmv_batch(&prep, &xs, Backend::Pars3 { p }).unwrap();
        for c in 0..3 {
            let diff = got
                .col(c)
                .iter()
                .zip(want.col(c))
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            assert!(diff <= 1e-12, "{listen}: batch col {c} diverged by {diff:.3e}");
        }
        let got = client.solve(&h, x.clone(), opts.clone(), Backend::Serial).wait().unwrap();
        let want = coord.solve(&prep, &x, &opts, Backend::Serial).unwrap();
        assert_eq!((got.converged, got.iters), (want.converged, want.iters), "{listen}");
        let diff =
            got.x.iter().zip(&want.x).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        assert!(diff <= 1e-12, "{listen}: solve diverged by {diff:.3e}");

        // describe's evidence tree crosses as JSON and reconstructs
        let info = client.describe(&h).wait().unwrap();
        assert_eq!((info.name.as_str(), info.n), ("m", n), "{listen}");
        assert_eq!(info.bw_before, prep.bw_before, "{listen}");
        assert_eq!(info.reordered_bw, prep.reordered_bw, "{listen}");
        assert!(!info.plan.summary().is_empty(), "{listen}");

        // stats, single-shard and all-shards
        let all = client.cache_stats_all().wait().unwrap();
        assert_eq!(all.len(), 2, "{listen}: one entry per shard");
        let one = client.cache_stats(h.shard()).wait().unwrap();
        assert_eq!(one.shard, h.shard(), "{listen}");

        // typed errors survive the wire as variants
        client.release(&h).wait().unwrap();
        match client.spmv(&h, x.clone(), Backend::Serial).wait() {
            Err(Pars3Error::StaleHandle { .. }) => {}
            other => panic!("{listen}: expected StaleHandle, got {:?}", other.map(|y| y.len())),
        }
        match client.spmv(&h, vec![0.0; 3], Backend::Serial).wait() {
            // released handle: staleness outranks the dimension check
            Err(Pars3Error::StaleHandle { .. }) => {}
            other => panic!("{listen}: expected StaleHandle, got {:?}", other.map(|y| y.len())),
        }

        server.stop();
        server.join();
    }
    let _ = std::fs::remove_dir_all(&dir);
}
