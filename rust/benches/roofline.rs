//! Bench: **roofline sweep** — every registered kernel, rated against
//! the machine's measured STREAM-triad bandwidth, across the four
//! `plan_quality` pattern families (banded / scattered / disconnected /
//! symmetric). Each run is timed through `Bencher::bench_rated` with
//! the kernel's own `flops()`/`bytes()` accounting, so the md/json
//! reports carry GF/s, GB/s and the achieved fraction of peak for
//! every (family, kernel) pair — the measured counterpart of the
//! "SSS moves half the bytes of CSR" argument (§2, Fig. 3).
//!
//! All kernels are constructed *by name* through the unified registry,
//! and all throughput math goes through `pars3::perf`; this bench
//! never divides by time itself.
//!
//! `PARS3_BENCH_SCALE` (float) overrides the problem size — the CI
//! smoke job runs this bench tiny (with `PARS3_PEAK_GBS` pinned so the
//! triad measurement is skipped) to keep it from bit-rotting.

use pars3::kernel::registry::{build_from_sss, KernelConfig, KERNEL_NAMES};
use pars3::kernel::Spmv;
use pars3::report::md_table;
use pars3::sparse::{convert, gen, skew, Symmetry};
use pars3::util::bencher::Bencher;
use pars3::util::SmallRng;
use std::sync::Arc;

fn main() {
    let mut scale = 1.0f64;
    if let Ok(s) = std::env::var("PARS3_BENCH_SCALE") {
        scale = s.parse().expect("PARS3_BENCH_SCALE must be a float");
    }
    let n = ((2000.0 * scale) as usize).max(96);
    let p = 4usize;
    let mut rng = SmallRng::seed_from_u64(7);
    let mut b = Bencher::new("roofline");
    let mut rows = Vec::new();

    for (family, n, edges) in gen::pattern_families(n, &mut rng) {
        let coo = skew::coo_from_pattern(n, &edges, 2.0, &mut rng);
        let sss = Arc::new(convert::coo_to_sss(&coo, Symmetry::Skew).expect("sss"));
        let bw = sss.bandwidth();
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.13).sin()).collect();
        let mut y = vec![0.0; n];
        let kcfg = KernelConfig::with_threads(p);

        for &name in KERNEL_NAMES {
            // dgbmv materializes a (2*bw+1)*n dense band — skip it where
            // the band array stops being representative (§2 trade-off)
            if name == "dgbmv" && bw.saturating_mul(n) >= 8_000_000 {
                continue;
            }
            let mut k = build_from_sss(name, sss.clone(), &kcfg).expect(name);
            let (flops, bytes) = (k.flops(), k.bytes());
            let (_, roof) = b.bench_rated(&format!("{family}/{name}"), 2, 5, flops, bytes, || {
                k.apply(&x, &mut y);
                std::hint::black_box(&y);
            });
            rows.push(vec![
                family.to_string(),
                name.to_string(),
                format!("{:.3}", roof.gflops),
                format!("{:.3}", roof.gbytes),
                format!("{:.1}%", 100.0 * roof.achieved_fraction),
                format!("{:.4}", roof.arithmetic_intensity),
            ]);
        }
    }

    b.section(&format!(
        "## Per-kernel roofline across pattern families\n\n{}",
        md_table(
            &["pattern", "kernel", "GF/s", "GB/s", "achieved", "AI flop/B"],
            &rows
        )
    ));
    b.section(
        "SpMV is memory-bound: the achieved column (fraction of the \
         measured STREAM-triad bandwidth) is the honest score — a GF/s \
         number alone flatters kernels that re-read the matrix. SSS-based \
         kernels should show higher AI than CSR (half the matrix bytes \
         per flop); a kernel far below the others on the same family has \
         a traffic problem, not a compute problem.\n",
    );
    b.finish();
}
