//! Bench: **Figs. 1 & 5** — RCM effectiveness: bandwidth reduction and
//! the *cache-locality* effect on the serial kernel (SpMV on the
//! scrambled vs the RCM-ordered matrix — the [4] observation the paper
//! builds on). Also shows the Fig. 5 point: already-banded inputs gain
//! little.

use pars3::coordinator::{Config, Coordinator};
use pars3::kernel::serial_sss::sss_spmv;
use pars3::report::{self, md_table};
use pars3::sparse::{convert, gen, skew, Symmetry};
use pars3::util::bencher::Bencher;
use pars3::util::SmallRng;

fn main() {
    let cfg = Config::default();
    let mut b = Bencher::new("rcm_effect");
    let coord = Coordinator::new(cfg.clone());
    let mut rows = Vec::new();

    for m in gen::paper_suite(cfg.scale) {
        let mut rng = SmallRng::seed_from_u64(cfg.seed ^ m.n as u64);
        let coo = skew::coo_from_pattern(m.n, &m.lower_edges, cfg.alpha, &mut rng);
        // scrambled-order SSS (pre-RCM)
        let sss_orig = convert::coo_to_sss(&coo, Symmetry::Skew).unwrap();
        let prep = coord.prepare(m.name, &coo).unwrap();
        let x: Vec<f64> = (0..m.n).map(|i| (i as f64 * 0.07).sin()).collect();
        let mut y = vec![0.0; m.n];

        let t_orig = b.bench(&format!("spmv-scrambled/{}", m.name), 2, 5, || {
            sss_spmv(&sss_orig, &x, &mut y);
            std::hint::black_box(&y);
        });
        let t_rcm = b.bench(&format!("spmv-rcm/{}", m.name), 2, 5, || {
            sss_spmv(&prep.sss, &x, &mut y);
            std::hint::black_box(&y);
        });
        rows.push(vec![
            m.name.to_string(),
            prep.bw_before.to_string(),
            prep.reordered_bw.to_string(),
            format!("{:.3e}", t_orig.min),
            format!("{:.3e}", t_rcm.min),
            format!("{:.2}x", t_orig.min / t_rcm.min),
        ]);
    }

    // Fig. 5's flip side: an input that is *already* banded gains ~nothing
    {
        let mut rng = SmallRng::seed_from_u64(99);
        let edges = gen::random_banded_pattern(4000, 4, 0.5, &mut rng);
        let coo = skew::coo_from_pattern(4000, &edges, cfg.alpha, &mut rng);
        let prep = coord.prepare("already_banded", &coo).unwrap();
        rows.push(vec![
            "already_banded".into(),
            prep.bw_before.to_string(),
            prep.reordered_bw.to_string(),
            "-".into(),
            "-".into(),
            "(structure preserved)".into(),
        ]);
    }

    b.section(&format!(
        "## RCM effect: bandwidth + serial-SpMV locality speedup\n\n{}",
        md_table(
            &["Matrix", "bw before", "bw after", "scrambled s", "RCM s", "locality gain"],
            &rows
        )
    ));

    let suite = report::prepared_suite(&cfg).expect("suite");
    b.section(&report::rcm_report(&suite));
    b.finish();
}
