//! Bench: **reordering strategy quality** — bandwidth/profile achieved
//! and downstream `pars3` SpMV time for every [`ReorderPolicy`] on
//! three pattern families:
//!
//! * `banded`    — already tightly banded (the case where reordering
//!                 buys nothing and `auto` should decline);
//! * `scattered` — a scrambled banded pattern plus long-range edges
//!                 (the paper's main case: reordering is the win);
//! * `disconnected` — several disjoint banded blocks, scrambled
//!                 (per-component reordering keeps each block tight).
//!
//! `PARS3_BENCH_SCALE` (float) overrides the problem size — the CI
//! smoke job runs this bench tiny to keep it from bit-rotting.

use pars3::coordinator::{Backend, Config, Coordinator};
use pars3::graph::reorder::ReorderPolicy;
use pars3::report::md_table;
use pars3::sparse::{gen, skew};
use pars3::util::bencher::Bencher;
use pars3::util::SmallRng;

fn patterns(n: usize, rng: &mut SmallRng) -> Vec<(&'static str, usize, Vec<(u32, u32)>)> {
    let banded = gen::random_banded_pattern(n, 4, 0.5, rng);
    let mut scattered = banded.clone();
    gen::add_long_range(&mut scattered, n, 0.05, rng);
    let scattered = gen::scramble(&scattered, n, rng);
    // three disjoint banded blocks, then scrambled as one matrix
    let block = n / 3;
    let mut disconnected = Vec::new();
    for b in 0..3u32 {
        let base = b * block as u32;
        for (i, j) in gen::random_banded_pattern(block, 3, 0.5, rng) {
            disconnected.push((i + base, j + base));
        }
    }
    let dn = 3 * block;
    let disconnected = gen::scramble(&disconnected, dn, rng);
    vec![("banded", n, banded), ("scattered", n, scattered), ("disconnected", dn, disconnected)]
}

fn main() {
    let mut scale = 1.0f64;
    if let Ok(s) = std::env::var("PARS3_BENCH_SCALE") {
        scale = s.parse().expect("PARS3_BENCH_SCALE must be a float");
    }
    let n = ((3000.0 * scale) as usize).max(90);
    let mut rng = SmallRng::seed_from_u64(7);
    let mut b = Bencher::new("reorder_quality");
    let mut rows = Vec::new();

    for (family, n, edges) in patterns(n, &mut rng) {
        let coo = skew::coo_from_pattern(n, &edges, 2.0, &mut rng);
        for policy in [
            ReorderPolicy::Natural,
            ReorderPolicy::Rcm,
            ReorderPolicy::RcmBiCriteria,
            ReorderPolicy::Auto,
        ] {
            let mut coord =
                Coordinator::new(Config { reorder: policy, ..Config::default() });
            let prep = coord.prepare(family, &coo).expect("prepare");
            let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.13).sin()).collect();
            // downstream value: the pars3 SpMV this ordering produces
            let t = b.bench(&format!("pars3-spmv/{family}/{policy}"), 1, 3, || {
                let y = coord.spmv(&prep, &x, Backend::Pars3 { p: 4 }).expect("spmv");
                std::hint::black_box(&y);
            });
            rows.push(vec![
                family.to_string(),
                policy.to_string(),
                prep.plan.reorder.strategy.to_string(),
                prep.bw_before.to_string(),
                prep.reordered_bw.to_string(),
                prep.plan.reorder.profile_after.to_string(),
                prep.plan.reorder.components.len().to_string(),
                format!("{:.3e}", t.min),
            ]);
        }
    }

    b.section(&format!(
        "## Reordering strategy quality (bandwidth achieved + downstream pars3 SpMV)\n\n{}",
        md_table(
            &[
                "pattern", "policy", "chosen", "bw before", "bw after", "profile",
                "components", "spmv s",
            ],
            &rows
        )
    ));
    b.section(
        "`auto` should decline on `banded` (chosen = natural), pick an \
         RCM family member on `scattered`, and on `disconnected` every \
         RCM-family row reorders each block independently. \
         `rcm-bicriteria` differs from `rcm` only through its start \
         nodes — compare the `bw after` columns for the start-node \
         value.\n",
    );
    b.finish();
}
