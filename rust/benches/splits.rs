//! Bench: **Figs. 4/6/7/8** — 3-way split structure: split sizes and
//! densities over an `outer_bw` sweep, and the serial split3 kernel's
//! sensitivity to the boundary (the paper's user bandwidth parameter).

use pars3::coordinator::Config;
use pars3::kernel::Split3;
use pars3::report::{self, md_table};
use pars3::util::bencher::Bencher;

fn main() {
    let cfg = Config::default();
    let suite = report::prepared_suite(&cfg).expect("suite");
    let mut b = Bencher::new("splits");

    // split construction cost + execution across the outer_bw sweep
    let (m, prep) = suite.iter().find(|(m, _)| m.name == "audikw_1_like").unwrap();
    let x: Vec<f64> = (0..prep.n).map(|i| (i as f64 * 0.19).cos()).collect();
    let mut rows = Vec::new();
    for outer_bw in [1usize, 3, 8, 16, 64] {
        let split = Split3::with_outer_bw(&prep.sss, outer_bw).unwrap();
        let t_build = b.bench(&format!("build/outer_bw={outer_bw}"), 1, 3, || {
            let s = Split3::with_outer_bw(&prep.sss, outer_bw).unwrap();
            std::hint::black_box(s.nnz_outer());
        });
        let mut y = vec![0.0; prep.n];
        let t_run = b.bench(&format!("spmv/outer_bw={outer_bw}"), 2, 5, || {
            split.spmv_serial(&x, &mut y);
            std::hint::black_box(&y);
        });
        rows.push(vec![
            outer_bw.to_string(),
            split.nnz_middle().to_string(),
            split.nnz_outer().to_string(),
            format!("{:.3e}", t_build.min),
            format!("{:.3e}", t_run.min),
        ]);
    }
    b.section(&format!(
        "## outer_bw sweep on {} (n={})\n\n{}",
        m.name,
        prep.n,
        md_table(&["outer_bw", "middle nnz", "outer nnz", "build s", "spmv s"], &rows)
    ));

    // ablation (paper §3.1.2 discussion): equal-rows vs equal-NNZ blocks
    use pars3::kernel::balance::{analyze, RowPartition};
    let mut rows = Vec::new();
    for (m, prep) in &suite {
        for p_ranks in [8usize, 32] {
            let br = analyze(&prep.split, &RowPartition::by_rows(prep.n, p_ranks));
            let bn = analyze(&prep.split, &RowPartition::by_nnz(&prep.split, p_ranks));
            rows.push(vec![
                m.name.to_string(),
                p_ranks.to_string(),
                format!("{:.3}", br.nnz_imbalance),
                format!("{:.3}", bn.nnz_imbalance),
                br.total_conflicts.to_string(),
                bn.total_conflicts.to_string(),
            ]);
        }
    }
    b.section(&format!(
        "## Ablation: equal-rows vs equal-NNZ distribution (imbalance = max/mean nnz)\n\n{}",
        md_table(
            &["Matrix", "P", "imb rows", "imb nnz", "conflicts rows", "conflicts nnz"],
            &rows
        )
    ));

    b.section(&report::splits_report(&suite, &[1, 3, 8, 16]));
    b.section(&report::conflict_report(&suite, &cfg.ranks));
    b.finish();
}
