//! Bench: **Table 1** — regenerate the benchmark-matrix characteristics
//! table and time the Θ(NNZ) preprocessing (RCM + split) per matrix.

use pars3::coordinator::{Config, Coordinator};
use pars3::report;
use pars3::sparse::{gen, skew};
use pars3::util::bencher::Bencher;
use pars3::util::SmallRng;

fn main() {
    let cfg = Config::default();
    let mut b = Bencher::new("table1");

    // time preprocessing per matrix (the amortized one-time cost)
    let coord = Coordinator::new(cfg.clone());
    for m in gen::paper_suite(cfg.scale) {
        let mut rng = SmallRng::seed_from_u64(cfg.seed ^ m.n as u64);
        let coo = skew::coo_from_pattern(m.n, &m.lower_edges, cfg.alpha, &mut rng);
        b.bench(&format!("preprocess/{}", m.name), 1, 3, || {
            let prep = coord.prepare(m.name, &coo).unwrap();
            std::hint::black_box(prep.reordered_bw);
        });
    }

    let suite = report::prepared_suite(&cfg).expect("suite");
    b.section(&report::table1(&suite));
    b.finish();
}
