//! Bench: **Figure 9** (the headline) — strong-scaling speedups of
//! PARS3 over serial Alg. 1 for P = 1..64 on the six analogues, via the
//! calibrated cost replay, plus per-plan preprocessing timings.

use pars3::coordinator::Config;
use pars3::kernel::pars3::Pars3Plan;
use pars3::kernel::registry::{build_from_split, KernelConfig};
use pars3::kernel::Spmv;
use pars3::mpisim::CostModel;
use pars3::report;
use pars3::util::bencher::Bencher;

fn main() {
    let cfg = Config::default();
    let suite = report::prepared_suite(&cfg).expect("suite");
    let mut b = Bencher::new("fig9_scaling");

    let biggest = suite.iter().max_by_key(|(_, p)| p.nnz_lower).unwrap();
    let model = CostModel::calibrate(&biggest.1.sss, 5);
    b.section(&format!(
        "calibrated: t_nnz={:.3}ns t_row={:.3}ns alpha={:.2}us beta={:.3}ns/B\n",
        model.t_nnz * 1e9,
        model.t_row * 1e9,
        model.alpha * 1e6,
        model.beta * 1e9
    ));

    // plan construction cost (Θ(NNZ) preprocessing at each P)
    for (m, prep) in &suite {
        b.bench(&format!("plan-p64/{}", m.name), 1, 3, || {
            let plan = Pars3Plan::new(prep.split.clone(), 64.min(prep.n)).unwrap();
            std::hint::black_box(plan.ranks.len());
        });
    }

    // emulated kernel execution (the per-iteration hot path, 1 core),
    // constructed by name through the unified registry
    for (m, prep) in &suite {
        let x: Vec<f64> = (0..prep.n).map(|i| (i as f64 * 0.29).cos()).collect();
        let mut y = vec![0.0; prep.n];
        let kcfg =
            KernelConfig { threads: 8, outer_bw: cfg.outer_bw, ..KernelConfig::default() };
        // reuse the split prepared_suite already computed
        let mut k = build_from_split(prep.split.clone(), &kcfg).expect("pars3 kernel");
        b.bench(&format!("pars3-emulated-p8/{}", m.name), 2, 5, || {
            k.apply(&x, &mut y);
            std::hint::black_box(&y);
        });
    }

    let f = report::fig9(&suite, &cfg.ranks, &model);
    b.section("### calibrated to THIS box (1-core-era compute rates)\n");
    b.section(&report::fig9_report(&f));

    // secondary series: the paper's platform profile (slower per-core
    // compute => relatively cheaper communication, the paper's regime)
    let fo = report::fig9(&suite, &cfg.ranks, &CostModel::opteron());
    b.section("### Opteron platform profile (paper's testbed class)\n");
    b.section(&report::fig9_report(&fo));

    // paper-shape checks, printed for EXPERIMENTS.md
    let series = |n: &str| &fo.series.iter().find(|(m, _)| m == n).unwrap().1;
    let af = series("af_5_k101_like");
    let last = *af.last().unwrap();
    b.section(&format!(
        "shape check (opteron profile): af_5_k101_like at P=64: {last:.1}x \
         (paper: ~19x); monotone growth: {}\n",
        af.windows(2).all(|w| w[1] >= w[0] * 0.95)
    ));
    b.finish();
}
