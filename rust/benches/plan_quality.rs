//! Bench: **planner honesty** — does the joint (reorder, format,
//! backend) plan match the measured-best triple?
//!
//! For each pattern family the all-auto planner picks a triple from
//! structural scores; this bench then *measures* every triple in the
//! plan space (reorder × format × backend, at the planner's thread
//! count) and reports the planner's pick, the measured-best pick, the
//! slowdown of trusting the planner, and the per-axis hit-rate across
//! families. Families:
//!
//! * `banded`       — already tightly banded (reordering should decline);
//! * `scattered`    — scrambled band + long-range edges (reordering wins);
//! * `disconnected` — disjoint banded blocks, scrambled;
//! * `symmetric`    — structurally symmetric 2D 5-point mesh (bandwidth
//!                    stays wide, kernel choice matters);
//! * `small_world`  — ring + random long-range rewires (the RACE case:
//!                    no banding exists, the level schedule should win).
//!
//! `PARS3_BENCH_SCALE` (float) overrides the problem size — the CI
//! smoke job runs this bench tiny to keep it from bit-rotting.

use pars3::coordinator::planner::backend_label;
use pars3::coordinator::{Backend, Config, Coordinator, PlanMode};
use pars3::graph::reorder::ReorderPolicy;
use pars3::kernel::FormatPolicy;
use pars3::report::md_table;
use pars3::sparse::{gen, skew};
use pars3::util::bencher::Bencher;
use pars3::util::SmallRng;

fn main() {
    let mut scale = 1.0f64;
    if let Ok(s) = std::env::var("PARS3_BENCH_SCALE") {
        scale = s.parse().expect("PARS3_BENCH_SCALE must be a float");
    }
    let n = ((2000.0 * scale) as usize).max(96);
    // the planner's default thread count (PlanConstraints::from_config);
    // the measured sweep must run the parallel backends at the same p
    // for the comparison to be apples-to-apples
    let p = 8usize;
    let mut rng = SmallRng::seed_from_u64(11);
    let mut b = Bencher::new("plan_quality");
    let mut rows = Vec::new();
    let (mut triple_hits, mut axis_hits, mut families) = (0usize, [0usize; 3], 0usize);

    let reorders = [ReorderPolicy::Natural, ReorderPolicy::Rcm, ReorderPolicy::RcmBiCriteria];
    let formats = [FormatPolicy::Dia, FormatPolicy::Sss];
    let backends = [
        Backend::Serial,
        Backend::Csr,
        Backend::Dgbmv,
        Backend::Coloring { p },
        Backend::Race { p },
        Backend::Pars3 { p },
    ];

    for (family, n, edges) in gen::pattern_families(n, &mut rng) {
        let coo = skew::coo_from_pattern(n, &edges, 2.0, &mut rng);
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.13).sin()).collect();

        // the planner's structural pick on the all-auto config
        let mut auto_coord = Coordinator::new(Config::default());
        let prep = auto_coord.prepare(family, &coo).expect("auto prepare");
        let planned = prep.choice;
        let planned_key =
            (planned.reorder.name(), planned.format.to_string(), backend_label(planned.backend));

        // measure EVERY triple in the plan space through the pinned
        // legacy path (fresh coordinator per triple: no cache sharing)
        let mut best: Option<(f64, (&str, String, String))> = None;
        let mut planned_time = f64::INFINITY;
        for reorder in reorders {
            for format in formats {
                let cfg = Config {
                    plan: PlanMode::Pinned,
                    reorder,
                    format,
                    ..Config::default()
                };
                let mut coord = Coordinator::new(cfg);
                let pinned = coord.prepare(family, &coo).expect("pinned prepare");
                for backend in backends {
                    let label = backend_label(backend);
                    let t = b.bench(
                        &format!("spmv/{family}/{}+{}+{}", reorder.name(), format, label),
                        1,
                        3,
                        || {
                            let y = coord.spmv(&pinned, &x, backend).expect("spmv");
                            std::hint::black_box(&y);
                        },
                    );
                    let key = (reorder.name(), format.to_string(), label);
                    if key == planned_key {
                        planned_time = t.min;
                    }
                    if best.as_ref().map(|(m, _)| t.min < *m).unwrap_or(true) {
                        best = Some((t.min, key));
                    }
                }
            }
        }
        let (best_time, best_key) = best.expect("at least one measured triple");

        families += 1;
        let hit = [
            planned_key.0 == best_key.0,
            planned_key.1 == best_key.1,
            planned_key.2 == best_key.2,
        ];
        for (h, a) in hit.iter().zip(axis_hits.iter_mut()) {
            *a += *h as usize;
        }
        triple_hits += hit.iter().all(|&h| h) as usize;
        rows.push(vec![
            family.to_string(),
            format!("{}+{}+{}", planned_key.0, planned_key.1, planned_key.2),
            format!("{}+{}+{}", best_key.0, best_key.1, best_key.2),
            format!("{:.3e}", planned_time),
            format!("{:.3e}", best_time),
            format!("{:.2}x", planned_time / best_time.max(f64::MIN_POSITIVE)),
            if hit.iter().all(|&h| h) { "yes" } else { "no" }.to_string(),
        ]);
    }

    b.section(&format!(
        "## Planner pick vs measured-best triple\n\n{}",
        md_table(
            &[
                "pattern", "planned", "measured best", "planned s", "best s", "slowdown",
                "triple match",
            ],
            &rows
        )
    ));
    b.section(&format!(
        "Per-axis hit-rate over {families} families: reorder {}/{families}, \
         format {}/{families}, backend {}/{families}; full-triple {triple_hits}/{families}. \
         The planner scores structure only (bytes moved, row-work balance) — a \
         miss with a small slowdown is acceptable; a large slowdown means a \
         scorer is dishonest. Re-run with `plan_probe > 0` semantics by \
         comparing against the probe-backed plan if a scorer drifts.\n",
        axis_hits[0], axis_hits[1], axis_hits[2]
    ));
    b.finish();
}
