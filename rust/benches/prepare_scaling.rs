//! Bench: **prepare scaling** — wall-clock of the one-time prepare
//! pipeline (pseudo-peripheral BFS + RCM + permutation + SSS build) as
//! the prepare-pool width grows, on a scrambled banded pattern (the
//! paper's main case: RCM has real work to do).
//!
//! Two invariants are asserted, not just reported:
//!
//! * the permutation and the built SSS arrays are **bit-identical** for
//!   every pool width (the parallel prepare is a pure speedup);
//! * the per-stage [`PrepareTimings`] ride the [`ReorderReport`] out of
//!   the pipeline (bfs/rcm/build all stamped).
//!
//! The report lands in `target/bench_reports/prepare_scaling.{md,json}`;
//! CI copies the JSON next to the repo-root `BENCH_prepare_scaling.json`
//! trajectory artifact. `PARS3_BENCH_SCALE` (float) overrides the
//! problem size — the CI smoke job runs this bench tiny.

use pars3::graph::reorder::ReorderPolicy;
use pars3::kernel::registry;
use pars3::report::md_table;
use pars3::sparse::{gen, skew};
use pars3::util::bencher::Bencher;
use pars3::util::{PrepPool, SmallRng};

fn main() {
    let mut scale = 1.0f64;
    if let Ok(s) = std::env::var("PARS3_BENCH_SCALE") {
        scale = s.parse().expect("PARS3_BENCH_SCALE must be a float");
    }
    let n = ((40000.0 * scale) as usize).max(600);
    let mut rng = SmallRng::seed_from_u64(11);
    let mut edges = gen::random_banded_pattern(n, 6, 0.5, &mut rng);
    gen::add_long_range(&mut edges, n, 0.02, &mut rng);
    let edges = gen::scramble(&edges, n, &mut rng);
    let coo = skew::coo_from_pattern(n, &edges, 2.0, &mut rng);

    let mut b = Bencher::new("prepare_scaling");
    let mut rows = Vec::new();

    // the serial reference: every wider pool must reproduce its output
    let serial_pool = PrepPool::serial();
    let (serial_perm, serial_sss, _) =
        registry::reorder_to_sss_with(&coo, ReorderPolicy::Rcm, 0.0, &serial_pool)
            .expect("serial prepare");
    let t_serial = b.bench("prepare/threads=1", 1, 3, || {
        let out = registry::reorder_to_sss_with(&coo, ReorderPolicy::Rcm, 0.0, &serial_pool)
            .expect("prepare");
        std::hint::black_box(&out);
    });

    for threads in [1usize, 2, 4] {
        let pool = PrepPool::new(threads);
        let (perm, sss, mut report) =
            registry::reorder_to_sss_with(&coo, ReorderPolicy::Rcm, 0.0, &pool)
                .expect("prepare");
        assert_eq!(perm, serial_perm, "threads={threads}: permutation must be bit-identical");
        assert_eq!(sss.row_ptr, serial_sss.row_ptr, "threads={threads}");
        assert_eq!(sss.col_ind, serial_sss.col_ind, "threads={threads}");
        assert_eq!(sss.vals, serial_sss.vals, "threads={threads}");
        assert!(report.timings.bfs_ms >= 0.0 && report.timings.build_ms > 0.0);
        let t = if threads == 1 {
            t_serial
        } else {
            b.bench(&format!("prepare/threads={threads}"), 1, 3, || {
                let out = registry::reorder_to_sss_with(&coo, ReorderPolicy::Rcm, 0.0, &pool)
                    .expect("prepare");
                std::hint::black_box(&out);
            })
        };
        // stamp the serial reference so the summary carries the speedup
        report.timings.serial_ms = t_serial.min * 1e3;
        rows.push(vec![
            threads.to_string(),
            format!("{:.3e}", t.min),
            format!("{:.3}", report.timings.bfs_ms),
            format!("{:.3}", report.timings.rcm_ms),
            format!("{:.3}", report.timings.build_ms),
            format!("{:.2}", t_serial.min / t.min),
        ]);
        println!("{}", report.timings.summary());
    }

    b.section(&format!(
        "## Prepare scaling (n = {n}, RCM + SSS build; permutation asserted \
         bit-identical to serial at every width)\n\n{}",
        md_table(
            &["threads", "prepare s (min)", "bfs ms", "rcm ms", "build ms", "speedup"],
            &rows
        )
    ));
    b.section(
        "The per-stage columns come from the last measured run's \
         `PrepareTimings` (the same struct `describe` and the wire \
         protocol expose); `speedup` is min-over-min against the \
         1-thread run of this same process.\n",
    );
    b.finish();
}
