//! Bench: **Fig. 3 / Alg. 1** — the serial SSS kernel baseline, with
//! the plain-CSR kernel and the LAPACK-style dgbmv band kernel for
//! context (memory-bound roofline comparison; SSS touches half the
//! matrix bytes of CSR). All kernels are constructed *by name* through
//! the unified registry (`pars3::kernel::registry`), so this bench
//! automatically covers any kernel added there.

use pars3::coordinator::Config;
use pars3::kernel::registry::{build_from_sss, KernelConfig};
use pars3::kernel::Spmv;
use pars3::report::{self, md_table};
use pars3::util::bencher::Bencher;

fn main() {
    let cfg = Config::default();
    let suite = report::prepared_suite(&cfg).expect("suite");
    let mut b = Bencher::new("serial_baseline");
    let mut rows = Vec::new();

    // serial registry kernels; dgbmv only where the dense band array
    // stays representative (its (2*bw+1)*n storage explodes on the
    // widest analogues — the §2 trade-off the bench demonstrates)
    for (m, prep) in &suite {
        let n = prep.n;
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.13).sin()).collect();
        let mut y = vec![0.0; n];
        let kcfg =
            KernelConfig { threads: 1, outer_bw: cfg.outer_bw, ..KernelConfig::default() };

        let mut timings = Vec::new();
        for &name in &["serial_sss", "csr", "dgbmv"] {
            if name == "dgbmv" && prep.reordered_bw >= 2_000 {
                continue;
            }
            let mut k = build_from_sss(name, prep.sss.clone(), &kcfg).expect(name);
            let (flops, bytes) = (k.flops(), k.bytes());
            let (t, roof) =
                b.bench_rated(&format!("{name}/{}", m.name), 2, 5, flops, bytes, || {
                    k.apply(&x, &mut y);
                    std::hint::black_box(&y);
                });
            timings.push((name, t, roof, flops, bytes));
        }

        // the split3 serial path (pars3's single-rank numerics) for the
        // same matrix, via the registry's pars3 kernel at p=1
        let mut k1 = build_from_sss("pars3", prep.sss.clone(), &kcfg).expect("pars3");
        let (f1, by1) = (k1.flops(), k1.bytes());
        let (t_split, _) = b.bench_rated(&format!("pars3-p1/{}", m.name), 2, 5, f1, by1, || {
            k1.apply(&x, &mut y);
            std::hint::black_box(&y);
        });

        let (t_sss, roof_sss, flops, bytes) = timings
            .iter()
            .find(|(n, ..)| *n == "serial_sss")
            .map(|&(_, t, r, f, by)| (t, r, f, by))
            .expect("serial_sss timing");
        let t_csr = timings
            .iter()
            .find(|(n, ..)| *n == "csr")
            .map(|&(_, t, ..)| t)
            .expect("csr timing");
        // both the min-based (best observed) and median-based rates, so
        // a noisy machine is visible in the report itself
        let th = pars3::perf::throughput(t_sss, flops, bytes);
        rows.push(vec![
            m.name.to_string(),
            format!("{:.3e}", t_sss.min),
            format!("{:.3e}", t_csr.min),
            format!("{:.3e}", t_split.min),
            format!("{:.2}", t_csr.min / t_sss.min),
            format!("{:.2}", th.gflops),
            format!("{:.2}", th.gflops_median),
            format!("{:.2}", th.gbytes),
            format!("{:.1}%", 100.0 * roof_sss.achieved_fraction),
        ]);
    }

    b.section(&format!(
        "## Serial kernels via the registry (Alg. 1 vs CSR vs pars3-p1)\n\n{}",
        md_table(
            &[
                "Matrix",
                "SSS s",
                "CSR s",
                "pars3-p1 s",
                "CSR/SSS",
                "SSS GF/s (min)",
                "SSS GF/s (median)",
                "SSS GB/s",
                "roofline",
            ],
            &rows
        )
    ));

    // dgbmv waste-ratio context (§2): dense-band storage trade-off.
    // Computed structurally — (2*bw+1)*n slots vs n diagonal + both
    // mirrored triangles — instead of materializing the band again.
    let mut waste_rows = Vec::new();
    for (m, prep) in &suite {
        if prep.reordered_bw >= 2_000 {
            continue;
        }
        let slots = (2 * prep.reordered_bw + 1) * prep.n;
        let filled = prep.n + 2 * prep.nnz_lower;
        let waste = 1.0 - filled as f64 / slots as f64;
        waste_rows.push(vec![m.name.to_string(), format!("{waste:.3}")]);
    }
    if !waste_rows.is_empty() {
        b.section(&format!(
            "## dgbmv wasted band slots (explicit zeros, §2)\n\n{}",
            md_table(&["Matrix", "waste ratio"], &waste_rows)
        ));
    }
    b.finish();
}
