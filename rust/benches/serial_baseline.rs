//! Bench: **Fig. 3 / Alg. 1** — the serial SSS kernel baseline, with the
//! plain-CSR kernel and the split3 serial path for context (memory-bound
//! roofline comparison; SSS touches half the matrix bytes of CSR).

use pars3::coordinator::Config;
use pars3::kernel::csr_spmv::csr_spmv;
use pars3::kernel::serial_sss::sss_spmv;
use pars3::kernel::{Spmv, Split3};
use pars3::report::{self, md_table};
use pars3::sparse::convert;
use pars3::util::bencher::Bencher;

fn main() {
    let cfg = Config::default();
    let suite = report::prepared_suite(&cfg).expect("suite");
    let mut b = Bencher::new("serial_baseline");
    let mut rows = Vec::new();

    for (m, prep) in &suite {
        let n = prep.n;
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.13).sin()).collect();
        let mut y = vec![0.0; n];

        let t_sss = b.bench(&format!("sss/{}", m.name), 2, 5, || {
            sss_spmv(&prep.sss, &x, &mut y);
            std::hint::black_box(&y);
        });

        let csr = convert::sss_to_csr(&prep.sss);
        let t_csr = b.bench(&format!("csr/{}", m.name), 2, 5, || {
            csr_spmv(&csr, &x, &mut y);
            std::hint::black_box(&y);
        });

        let split = Split3::with_outer_bw(&prep.sss, cfg.outer_bw).unwrap();
        let t_split = b.bench(&format!("split3-serial/{}", m.name), 2, 5, || {
            split.spmv_serial(&x, &mut y);
            std::hint::black_box(&y);
        });

        // LAPACK-style dgbmv baseline (§2): dense-band storage trade-off.
        // Skip the widest analogues — their (2*bw+1)*n dense band array
        // would not be representative (waste ratio ~1).
        if prep.rcm_bw < 2_000 {
            let dg = pars3::kernel::dgbmv::BandedDgbmv::from_sss(&prep.sss).unwrap();
            let t_dg = b.bench(&format!("dgbmv/{}", m.name), 1, 3, || {
                dg.spmv(&x, &mut y);
                std::hint::black_box(&y);
            });
            b.section(&format!(
                "dgbmv {}: waste ratio {:.3}, {:.2}x vs SSS\n",
                m.name,
                dg.waste_ratio(),
                t_dg.min / t_sss.min
            ));
        }

        let k = pars3::kernel::serial_sss::SerialSss::new(prep.sss.clone());
        let th = pars3::perf::throughput(t_sss, k.flops(), k.bytes());
        rows.push(vec![
            m.name.to_string(),
            format!("{:.3e}", t_sss.min),
            format!("{:.3e}", t_csr.min),
            format!("{:.3e}", t_split.min),
            format!("{:.2}", t_csr.min / t_sss.min),
            format!("{:.2}", th.gflops),
            format!("{:.2}", th.gbytes),
        ]);
    }

    b.section(&format!(
        "## Serial kernels (Alg. 1 vs CSR vs split3-serial)\n\n{}",
        md_table(
            &["Matrix", "SSS s", "CSR s", "split3 s", "CSR/SSS", "SSS GFLOP/s", "SSS GB/s"],
            &rows
        )
    ));
    b.finish();
}
