//! Bench: **§4.1 comparison (X1)** — PARS3 vs the graph-coloring
//! conflict-free SSpMV [3]: modeled speedups at every rank count plus
//! real single-core executor timings and coloring statistics.

use pars3::coordinator::Config;
use pars3::graph::coloring::color_rows;
use pars3::kernel::registry::{build_from_split, build_from_sss, KernelConfig};
use pars3::kernel::Spmv;
use pars3::mpisim::CostModel;
use pars3::report::{self, md_table};
use pars3::util::bencher::Bencher;

fn main() {
    let cfg = Config::default();
    let suite = report::prepared_suite(&cfg).expect("suite");
    let mut b = Bencher::new("coloring_vs_pars3");

    let biggest = suite.iter().max_by_key(|(_, p)| p.nnz_lower).unwrap();
    let model = CostModel::calibrate(&biggest.1.sss, 5);

    // coloring preprocessing cost + phase counts (the baseline's weakness)
    let mut rows = Vec::new();
    for (m, prep) in &suite {
        let t = b.bench(&format!("color-rows/{}", m.name), 1, 3, || {
            let c = color_rows(&prep.sss);
            std::hint::black_box(c.num_colors);
        });
        let c = color_rows(&prep.sss);
        rows.push(vec![
            m.name.to_string(),
            c.num_colors.to_string(),
            format!("{:.3e}", t.min),
            prep.reordered_bw.to_string(),
        ]);
    }
    b.section(&format!(
        "## Coloring statistics (phases = barriers per multiply)\n\n{}",
        md_table(&["Matrix", "phases", "coloring time s", "RCM bw"], &rows)
    ));

    // real executor timings at p=4, single core (overhead comparison),
    // both kernels constructed by name through the registry
    for (m, prep) in suite.iter().take(2) {
        let x: Vec<f64> = (0..prep.n).map(|i| (i as f64 * 0.11).sin()).collect();
        let mut y = vec![0.0; prep.n];
        let kcfg =
            KernelConfig { threads: 4, outer_bw: cfg.outer_bw, ..KernelConfig::default() };
        // pars3 reuses the already-computed split; coloring needs the SSS
        let mut kernels = vec![
            build_from_split(prep.split.clone(), &kcfg).expect("pars3"),
            build_from_sss("coloring", prep.sss.clone(), &kcfg).expect("coloring"),
        ];
        for k in &mut kernels {
            let name = k.name();
            b.bench(&format!("{name}-emulated-p4/{}", m.name), 2, 5, || {
                k.apply(&x, &mut y);
                std::hint::black_box(&y);
            });
        }
    }

    b.section(&report::coloring_compare(&suite, &cfg.ranks, &model));
    b.finish();
}
