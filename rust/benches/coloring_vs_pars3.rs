//! Bench: **§4.1 comparison (X1)** — PARS3 vs the graph-coloring
//! conflict-free SSpMV [3]: modeled speedups at every rank count plus
//! real single-core executor timings and coloring statistics.

use pars3::coordinator::Config;
use pars3::graph::coloring::color_rows;
use pars3::kernel::coloring_spmv::ColoringPlan;
use pars3::kernel::pars3::Pars3Plan;
use pars3::mpisim::CostModel;
use pars3::report::{self, md_table};
use pars3::util::bencher::Bencher;

fn main() {
    let cfg = Config::default();
    let suite = report::prepared_suite(&cfg).expect("suite");
    let mut b = Bencher::new("coloring_vs_pars3");

    let biggest = suite.iter().max_by_key(|(_, p)| p.nnz_lower).unwrap();
    let model = CostModel::calibrate(&biggest.1.sss, 5);

    // coloring preprocessing cost + phase counts (the baseline's weakness)
    let mut rows = Vec::new();
    for (m, prep) in &suite {
        let t = b.bench(&format!("color-rows/{}", m.name), 1, 3, || {
            let c = color_rows(&prep.sss);
            std::hint::black_box(c.num_colors);
        });
        let c = color_rows(&prep.sss);
        rows.push(vec![
            m.name.to_string(),
            c.num_colors.to_string(),
            format!("{:.3e}", t.min),
            prep.rcm_bw.to_string(),
        ]);
    }
    b.section(&format!(
        "## Coloring statistics (phases = barriers per multiply)\n\n{}",
        md_table(&["Matrix", "phases", "coloring time s", "RCM bw"], &rows)
    ));

    // real executor timings at p=4, single core (overhead comparison)
    for (m, prep) in suite.iter().take(2) {
        let x: Vec<f64> = (0..prep.n).map(|i| (i as f64 * 0.11).sin()).collect();
        let pars3_plan = Pars3Plan::new(prep.split.clone(), 4).unwrap();
        b.bench(&format!("pars3-emulated-p4/{}", m.name), 2, 5, || {
            let (y, _) = pars3_plan.execute_emulated(&x);
            std::hint::black_box(y.len());
        });
        let color_plan = ColoringPlan::new(prep.sss.clone(), 4).unwrap();
        b.bench(&format!("coloring-emulated-p4/{}", m.name), 2, 5, || {
            let y = color_plan.execute_emulated(&x);
            std::hint::black_box(y.len());
        });
    }

    b.section(&report::coloring_compare(&suite, &cfg.ranks, &model));
    b.finish();
}
