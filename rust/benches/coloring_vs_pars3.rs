//! Bench: **§4.1 comparison (X1)** — PARS3 vs the graph-coloring
//! conflict-free SSpMV [3]: modeled speedups at every rank count plus
//! real single-core executor timings, coloring statistics, and the
//! three-way greedy / RACE / PARS3 sweep over the banded, scattered,
//! and small-world pattern families (the matrices where each strategy
//! is supposed to win).
//!
//! `PARS3_BENCH_SCALE` (float) overrides the sweep problem size — the
//! CI smoke job runs this bench tiny to keep it from bit-rotting.

use pars3::coordinator::Config;
use pars3::graph::coloring::color_rows;
use pars3::graph::reorder::ReorderPolicy;
use pars3::kernel::race::RaceStructure;
use pars3::kernel::registry::{self, build_from_split, build_from_sss, KernelConfig};
use pars3::kernel::Spmv;
use pars3::mpisim::CostModel;
use pars3::report::{self, md_table};
use pars3::sparse::{gen, skew};
use pars3::util::bencher::Bencher;
use pars3::util::SmallRng;
use std::sync::Arc;

fn main() {
    let cfg = Config::default();
    let mut scale = 1.0f64;
    if let Ok(s) = std::env::var("PARS3_BENCH_SCALE") {
        scale = s.parse().expect("PARS3_BENCH_SCALE must be a float");
    }
    let suite = report::prepared_suite(&cfg).expect("suite");
    let mut b = Bencher::new("coloring_vs_pars3");

    let biggest = suite.iter().max_by_key(|(_, p)| p.nnz_lower).unwrap();
    let model = CostModel::calibrate(&biggest.1.sss, 5);

    // coloring preprocessing cost + phase counts (the baseline's weakness)
    let mut rows = Vec::new();
    for (m, prep) in &suite {
        let t = b.bench(&format!("color-rows/{}", m.name), 1, 3, || {
            let c = color_rows(&prep.sss);
            std::hint::black_box(c.num_colors);
        });
        let c = color_rows(&prep.sss);
        rows.push(vec![
            m.name.to_string(),
            c.num_colors.to_string(),
            format!("{:.3e}", t.min),
            prep.reordered_bw.to_string(),
        ]);
    }
    b.section(&format!(
        "## Coloring statistics (phases = barriers per multiply)\n\n{}",
        md_table(&["Matrix", "phases", "coloring time s", "RCM bw"], &rows)
    ));

    // real executor timings at p=4, single core (overhead comparison),
    // both kernels constructed by name through the registry
    for (m, prep) in suite.iter().take(2) {
        let x: Vec<f64> = (0..prep.n).map(|i| (i as f64 * 0.11).sin()).collect();
        let mut y = vec![0.0; prep.n];
        let kcfg =
            KernelConfig { threads: 4, outer_bw: cfg.outer_bw, ..KernelConfig::default() };
        // pars3 reuses the already-computed split; coloring needs the SSS
        let mut kernels = vec![
            build_from_split(prep.split.clone(), &kcfg).expect("pars3"),
            build_from_sss("coloring", prep.sss.clone(), &kcfg).expect("coloring"),
        ];
        for k in &mut kernels {
            let name = k.name();
            b.bench(&format!("{name}-emulated-p4/{}", m.name), 2, 5, || {
                k.apply(&x, &mut y);
                std::hint::black_box(&y);
            });
        }
    }

    // three-way sweep: greedy coloring vs RACE vs PARS3 on the three
    // families where the contest is interesting — banded (PARS3's
    // home turf), scattered (reordering declines) and small-world
    // (RACE's target). All kernels constructed by name through the
    // registry; phase counts come from the same structures the kernels
    // execute.
    let sweep_n = ((1200.0 * scale) as usize).max(96);
    let mut rng = SmallRng::seed_from_u64(23);
    let mut rows3 = Vec::new();
    for (family, n, edges) in gen::pattern_families(sweep_n, &mut rng) {
        if !matches!(family, "banded" | "scattered" | "small_world") {
            continue;
        }
        let coo = skew::coo_from_pattern(n, &edges, 2.0, &mut rng);
        let (_, sss, _) =
            registry::reorder_to_sss(&coo, ReorderPolicy::Auto, cfg.reorder_min_gain)
                .expect("reorder");
        let sss = Arc::new(sss);
        let colors = color_rows(&sss).num_colors;
        let st = RaceStructure::build(&sss, 4);
        let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.17).sin()).collect();
        let mut y = vec![0.0; n];
        let kcfg =
            KernelConfig { threads: 4, outer_bw: cfg.outer_bw, ..KernelConfig::default() };
        let mut times = Vec::new();
        for name in ["coloring", "race", "pars3"] {
            let mut k = build_from_sss(name, sss.clone(), &kcfg).expect(name);
            let t = b.bench(&format!("three-way/{family}/{name}"), 2, 5, || {
                k.apply(&x, &mut y);
                std::hint::black_box(&y);
            });
            times.push(t.min);
        }
        rows3.push(vec![
            family.to_string(),
            n.to_string(),
            colors.to_string(),
            st.phases().to_string(),
            st.depth.to_string(),
            format!("{:.3e}", times[0]),
            format!("{:.3e}", times[1]),
            format!("{:.3e}", times[2]),
        ]);
    }
    b.section(&format!(
        "## Three-way sweep: greedy coloring vs RACE vs PARS3 (emulated, p=4)\n\n{}\n\n\
         Greedy pays one barrier per color; RACE pays one per parity \
         phase (at most 2) and keeps level order for locality.\n",
        md_table(
            &[
                "pattern", "n", "greedy colors", "race phases", "race depth", "coloring s",
                "race s", "pars3 s",
            ],
            &rows3
        )
    ));

    b.section(&report::coloring_compare(&suite, &cfg.ranks, &model));
    b.finish();
}
