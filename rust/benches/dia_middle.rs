//! Bench: **hybrid DIA vs pure SSS middle-split** applies at k = 1 and
//! k = 8, for the kernels whose inner loop walks the band interior
//! (`serial_sss`, `pars3`). The DIA rows replace the per-entry
//! `col_ind` gather with two unit-stride passes per dense diagonal, so
//! `dia-k*` vs `sss-k*` on the same matrix is the measured value of the
//! diagonal-major storage — the fill-ratio heuristic (`--format auto`)
//! picks whichever side wins per matrix.
//!
//! `PARS3_BENCH_SCALE` (float) overrides the suite scale — the CI
//! smoke job runs this bench at a tiny scale to keep the bench targets
//! from bit-rotting without burning minutes.

use pars3::coordinator::Config;
use pars3::kernel::registry::{build_from_sss, KernelConfig};
use pars3::kernel::{FormatPolicy, Split3, Spmv, VecBatch};
use pars3::report;
use pars3::util::bencher::Bencher;

fn main() {
    let mut cfg = Config::default();
    if let Ok(s) = std::env::var("PARS3_BENCH_SCALE") {
        cfg.scale = s.parse().expect("PARS3_BENCH_SCALE must be a float");
    }
    let suite = report::prepared_suite(&cfg).expect("suite");
    let mut b = Bencher::new("dia_middle");

    for (m, prep) in suite.iter().take(3) {
        let n = prep.n;
        // record what the Auto heuristic would pick for this matrix
        let auto = Split3::with_outer_bw_format(&prep.sss, cfg.outer_bw, FormatPolicy::Auto)
            .expect("split");
        let auto_note = match &auto.dia {
            Some(dia) => format!(
                "{}: auto picks dia ({} dense diagonals, fill {:.2}, {} nnz in remainder)\n",
                m.name,
                dia.diags.len(),
                dia.fill_ratio(),
                dia.rest.nnz_lower()
            ),
            None => format!("{}: auto picks sss (no diagonal clears the fill threshold)\n", m.name),
        };
        b.section(&auto_note);
        for (fmt, policy) in [("dia", FormatPolicy::Dia), ("sss", FormatPolicy::Sss)] {
            let kcfg = KernelConfig {
                threads: 4,
                outer_bw: cfg.outer_bw,
                threaded: cfg.threaded,
                format: policy,
                ..KernelConfig::default()
            };
            for name in ["serial_sss", "pars3"] {
                let mut kern = build_from_sss(name, prep.sss.clone(), &kcfg).expect(name);
                let (flops, bytes) = (kern.flops(), kern.bytes());
                for &k in &[1usize, 8] {
                    let xs = VecBatch::from_fn(n, k, |i, c| {
                        ((i * 29 + c * 11) % 19) as f64 * 0.25 - 2.0
                    });
                    let mut ys = VecBatch::zeros(n, k);
                    kern.prepare_hint(k);
                    let label = format!("{name}/{fmt}-k{k}/{}", m.name);
                    if k == 1 {
                        // rated against the kernel's own per-apply
                        // accounting — exact only for a single column
                        b.bench_rated(&label, 1, 3, flops, bytes, || {
                            kern.apply_batch(&xs, &mut ys);
                            std::hint::black_box(ys.data());
                        });
                    } else {
                        b.bench(&label, 1, 3, || {
                            kern.apply_batch(&xs, &mut ys);
                            std::hint::black_box(ys.data());
                        });
                    }
                }
            }
        }
    }
    b.section(
        "dia-k* vs sss-k* is the middle-split storage win: unit-stride \
         FMA passes over dense diagonals (zero index loads) vs the \
         col_ind gather loop. DIA loses when the band is scattered — \
         which is exactly when `--format auto` keeps SSS.\n",
    );
    b.finish();
}
