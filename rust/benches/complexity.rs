//! Bench: **X2** — Θ(NNZ) complexity claims: serial kernel time per NNZ
//! stays flat across problem sizes, and preprocessing (RCM + split +
//! conflict analysis) is Θ(NNZ) too.

use pars3::coordinator::{Config, Coordinator};
use pars3::kernel::conflict::ConflictMap;
use pars3::report::{self, md_table};
use pars3::sparse::gen;
use pars3::util::bencher::Bencher;

fn main() {
    let cfg = Config::default();
    let mut b = Bencher::new("complexity");
    let coord = Coordinator::new(cfg.clone());

    // preprocessing linearity
    let mut rows = Vec::new();
    for n in [1000usize, 2000, 4000, 8000] {
        let coo = gen::small_test_matrix(n, cfg.seed, cfg.alpha);
        let t_prep = b.bench(&format!("preprocess/n={n}"), 1, 3, || {
            let p = coord.prepare("cx", &coo).unwrap();
            std::hint::black_box(p.reordered_bw);
        });
        let prep = coord.prepare("cx", &coo).unwrap();
        let t_conf = b.bench(&format!("conflict-analysis/n={n}"), 1, 3, || {
            let cm = ConflictMap::analyze(&prep.split, 16);
            std::hint::black_box(cm.total_conflicts());
        });
        rows.push(vec![
            n.to_string(),
            prep.nnz_lower.to_string(),
            format!("{:.1}", t_prep.min / prep.nnz_lower as f64 * 1e9),
            format!("{:.1}", t_conf.min / prep.nnz_lower as f64 * 1e9),
        ]);
    }
    b.section(&format!(
        "## Θ(NNZ) preprocessing (ns per nnz should stay ~flat)\n\n{}",
        md_table(&["n", "nnz_lower", "prep ns/nnz", "conflict ns/nnz"], &rows)
    ));

    // kernel linearity (report::complexity_report regenerates as table)
    b.section(&report::complexity_report(&cfg, &[500, 1000, 2000, 4000, 8000]).unwrap());
    b.finish();
}
