//! Bench: **fused multi-vector `apply_batch` vs repeated `apply`** for
//! every registered kernel. The fused path traverses the matrix once
//! per batch (and, for `pars3`, exchanges halos once), so its win over
//! the looped baseline is the measured value of the zero-copy batch
//! engine on block-Krylov / multi-RHS workloads.
//!
//! `PARS3_BENCH_SCALE` (float) overrides the suite scale — the CI
//! smoke job runs this bench at a tiny scale to keep the bench targets
//! from bit-rotting without burning minutes.

use pars3::coordinator::Config;
use pars3::kernel::registry::{build_from_sss, KernelConfig};
use pars3::kernel::{Spmv, VecBatch, KERNEL_NAMES};
use pars3::report;
use pars3::util::bencher::Bencher;

fn main() {
    let mut cfg = Config::default();
    if let Ok(s) = std::env::var("PARS3_BENCH_SCALE") {
        cfg.scale = s.parse().expect("PARS3_BENCH_SCALE must be a float");
    }
    let suite = report::prepared_suite(&cfg).expect("suite");
    let mut b = Bencher::new("batch_apply");

    for (m, prep) in suite.iter().take(3) {
        let n = prep.n;
        let kcfg = KernelConfig {
            threads: 4,
            outer_bw: cfg.outer_bw,
            threaded: cfg.threaded,
            ..KernelConfig::default()
        };
        for &name in KERNEL_NAMES {
            // dgbmv's dense band array explodes on wide analogues (§2)
            if name == "dgbmv" && prep.reordered_bw >= 2_000 {
                continue;
            }
            // prep.sss is Arc-shared: constructing a kernel per name no
            // longer clones the matrix
            let mut kern = build_from_sss(name, prep.sss.clone(), &kcfg).expect(name);
            for &k in &[1usize, 8] {
                let xs =
                    VecBatch::from_fn(n, k, |i, c| ((i * 31 + c * 7) % 17) as f64 * 0.25 - 2.0);
                let mut ys = VecBatch::zeros(n, k);
                kern.prepare_hint(k);
                b.bench(&format!("{name}/fused-k{k}/{}", m.name), 1, 3, || {
                    kern.apply_batch(&xs, &mut ys);
                    std::hint::black_box(ys.data());
                });
                let mut y = vec![0.0; n];
                b.bench(&format!("{name}/looped-k{k}/{}", m.name), 1, 3, || {
                    for c in 0..k {
                        kern.apply(xs.col(c), &mut y);
                    }
                    std::hint::black_box(&y);
                });
            }
        }
    }
    b.section(
        "fused-k8 vs looped-k8 is the batch-fusion win: one matrix \
         traversal (and one pars3 halo round) per batch instead of 8.\n",
    );
    b.finish();
}
