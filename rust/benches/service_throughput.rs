//! Bench: **pipelined vs serialized** request streams through the
//! sharded service, at 1 shard and at W shards.
//!
//! `serialized` is the old one-in-flight `call()` pattern: submit,
//! block, repeat — every request pays a full client↔worker round trip
//! of latency and the shards can never overlap. `pipelined` submits the
//! whole burst as tickets first and collects afterwards, so requests
//! queue back-to-back on each shard and **different shards execute
//! concurrently** — `pipelined/shards4` vs `serialized/shards1` is the
//! measured value of the handle-based ticket API. Matrices are placed
//! round-robin, so the burst spreads across every shard.
//!
//! `PARS3_BENCH_SCALE` (float) overrides the suite scale — the CI
//! smoke job runs this bench at a tiny scale to keep the bench targets
//! from bit-rotting without burning minutes.

use pars3::coordinator::{Backend, Config, Service};
use pars3::sparse::{gen, skew};
use pars3::util::bencher::Bencher;
use pars3::util::SmallRng;

fn main() {
    let mut cfg = Config::default();
    if let Ok(s) = std::env::var("PARS3_BENCH_SCALE") {
        cfg.scale = s.parse().expect("PARS3_BENCH_SCALE must be a float");
    }
    let suite = gen::paper_suite(cfg.scale);
    // four matrices so a 4-shard pool has one per shard
    let matrices: Vec<(String, pars3::sparse::Coo, Vec<f64>)> = suite
        .iter()
        .take(4)
        .map(|m| {
            let mut rng = SmallRng::seed_from_u64(cfg.seed ^ m.n as u64);
            let coo = skew::coo_from_pattern(m.n, &m.lower_edges, cfg.alpha, &mut rng);
            let x: Vec<f64> = (0..m.n).map(|i| (i as f64 * 0.13).sin()).collect();
            (m.name.to_string(), coo, x)
        })
        .collect();

    let mut b = Bencher::new("service_throughput");
    let backend = Backend::Pars3 { p: 4 };
    let requests = 32usize; // per measured run (fits the default queue)

    for shards in [1usize, 4] {
        let svc = Service::start(Config { shards, ..cfg.clone() });
        let client = svc.client();
        let handles: Vec<_> = matrices
            .iter()
            .map(|(name, coo, _)| client.prepare(name, coo.clone()).wait().expect("prepare"))
            .collect();
        // warm every shard's kernel cache so both patterns measure the
        // serving path, not first-touch kernel construction
        for (h, (_, _, x)) in handles.iter().zip(&matrices) {
            client.spmv(h, x.clone(), backend).wait().expect("warmup spmv");
        }

        b.bench(&format!("serialized/shards{shards}"), 1, 3, || {
            for r in 0..requests {
                let i = r % handles.len();
                let y = client
                    .spmv(&handles[i], matrices[i].2.clone(), backend)
                    .wait()
                    .expect("spmv");
                std::hint::black_box(y.len());
            }
        });

        b.bench(&format!("pipelined/shards{shards}"), 1, 3, || {
            let tickets: Vec<_> = (0..requests)
                .map(|r| {
                    let i = r % handles.len();
                    client.spmv(&handles[i], matrices[i].2.clone(), backend)
                })
                .collect();
            for t in tickets {
                std::hint::black_box(t.wait().expect("spmv").len());
            }
        });

        svc.shutdown();
    }

    b.section(
        "pipelined vs serialized is the ticket-API win: submissions \
         queue back-to-back instead of paying one client<->worker round \
         trip of latency each, and with W shards the per-matrix streams \
         execute concurrently. Submission applies backpressure only when \
         a shard's bounded queue fills (queue_depth).\n",
    );
    b.finish();
}
