//! Bench: the same pipelined burst through **in-process shard queues vs
//! a Unix-domain socket vs TCP loopback** — the measured cost of
//! putting the service behind the wire protocol.
//!
//! Every transport drives the identical [`ClientApi`] code path: submit
//! the whole burst as tickets, then collect. What changes is only the
//! boundary — function call + bounded queue, UDS frames, or TCP frames
//! (with the kernel's checksumming and flow control). `spmv_k1` is the
//! latency-sensitive shape (16n bytes per round trip); `spmv_batch_k8`
//! amortizes the per-message cost over 8 fused right-hand sides, which
//! is how a remote caller should batch when it can.
//!
//! `PARS3_BENCH_SCALE` (float) overrides the suite scale — the CI
//! smoke job runs this bench at a tiny scale to keep the bench targets
//! from bit-rotting without burning minutes.

use pars3::coordinator::{Backend, ClientApi, Config, Service};
use pars3::kernel::VecBatch;
use pars3::net::{Listen, RemoteClient, Server};
use pars3::sparse::{gen, skew, Coo};
use pars3::util::bencher::Bencher;
use pars3::util::SmallRng;

fn run_transport(
    b: &mut Bencher,
    transport: &str,
    client: &impl ClientApi,
    coo: &Coo,
    x: &[f64],
    xs: &VecBatch,
) {
    let backend = Backend::Pars3 { p: 4 };
    let requests = 16usize;
    let batch_requests = 4usize;
    let handle = client.prepare("bench", coo.clone()).wait().expect("prepare");
    // warm the kernel cache: measure serving, not first-touch builds
    client.spmv(&handle, x.to_vec(), backend).wait().expect("warmup");

    b.bench(&format!("spmv_k1/{transport}"), 1, 3, || {
        let tickets: Vec<_> =
            (0..requests).map(|_| client.spmv(&handle, x.to_vec(), backend)).collect();
        for t in tickets {
            std::hint::black_box(t.wait().expect("spmv").len());
        }
    });

    b.bench(&format!("spmv_batch_k8/{transport}"), 1, 3, || {
        let tickets: Vec<_> = (0..batch_requests)
            .map(|_| client.spmv_batch(&handle, xs.clone(), backend))
            .collect();
        for t in tickets {
            std::hint::black_box(t.wait().expect("spmv_batch").k());
        }
    });

    client.release(&handle).wait().expect("release");
}

fn main() {
    let mut cfg = Config::default();
    if let Ok(s) = std::env::var("PARS3_BENCH_SCALE") {
        cfg.scale = s.parse().expect("PARS3_BENCH_SCALE must be a float");
    }
    cfg.shards = 2;
    let suite = gen::paper_suite(cfg.scale);
    let m = &suite[3]; // af analogue: banded, quick to prepare
    let mut rng = SmallRng::seed_from_u64(cfg.seed ^ m.n as u64);
    let coo = skew::coo_from_pattern(m.n, &m.lower_edges, cfg.alpha, &mut rng);
    let x: Vec<f64> = (0..m.n).map(|i| (i as f64 * 0.13).sin()).collect();
    let xs = VecBatch::from_fn(m.n, 8, |i, c| ((i * 8 + c) as f64 * 0.07).cos());

    let mut b = Bencher::new("remote_throughput");

    {
        let svc = Service::start(cfg.clone());
        let client = svc.client();
        run_transport(&mut b, "inproc", &client, &coo, &x, &xs);
        svc.shutdown();
    }

    {
        let dir = std::env::temp_dir().join(format!("pars3-bench-uds-{}", std::process::id()));
        std::fs::create_dir_all(&dir).expect("tmpdir");
        let listen = Listen::Uds(dir.join("bench.sock"));
        let server = Server::bind(&listen, cfg.clone()).expect("bind uds");
        let client = RemoteClient::connect(&listen).expect("connect uds");
        run_transport(&mut b, "uds", &client, &coo, &x, &xs);
        drop(client);
        server.stop();
        server.join();
        let _ = std::fs::remove_dir_all(&dir);
    }

    {
        let server =
            Server::bind(&Listen::Tcp("127.0.0.1:0".to_string()), cfg).expect("bind tcp");
        let client = RemoteClient::connect(server.local_addr()).expect("connect tcp");
        run_transport(&mut b, "tcp", &client, &coo, &x, &xs);
        drop(client);
        server.stop();
        server.join();
    }

    b.section(
        "inproc vs uds vs tcp is the price of the process boundary: the \
         burst code is identical (ClientApi), only the transport differs. \
         k=1 spmv pays one 16n-byte round trip per multiply, so the \
         socket transports sit closest to inproc when requests pipeline \
         back-to-back; k=8 spmv_batch amortizes framing and syscalls \
         over 8 fused right-hand sides and narrows the gap further. UDS \
         beats TCP at small messages (no checksums or flow-control \
         machinery on loopback).\n",
    );
    b.finish();
}
