//! Figure 9 regeneration: strong-scaling sweep over the Table-1
//! analogue suite, using the calibrated cost replay for P up to 64,
//! validated against real threaded runs at small P.
//!
//! ```text
//! cargo run --release --example scaling_sweep [-- scale]
//! ```

use pars3::kernel::pars3::Pars3Plan;
use pars3::kernel::serial_sss::sss_spmv;
use pars3::mpisim::CostModel;
use pars3::perf::time_fn;
use pars3::report;
use pars3::coordinator::Config;
use std::sync::Arc;

fn main() -> pars3::Result<()> {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let cfg = Config { scale, ..Config::default() };
    println!("generating + preprocessing the 6-matrix suite at scale {scale}...");
    let suite = report::prepared_suite(&cfg)?;

    let biggest = suite.iter().max_by_key(|(_, p)| p.nnz_lower).unwrap();
    let model = CostModel::calibrate(&biggest.1.sss, 5);
    println!(
        "calibrated cost model: t_nnz={:.2}ns t_row={:.2}ns (alpha={:.1}us beta={:.2}ns/B)",
        model.t_nnz * 1e9,
        model.t_row * 1e9,
        model.alpha * 1e6,
        model.beta * 1e9
    );

    let ranks = cfg.ranks.clone();
    let f = report::fig9(&suite, &ranks, &model);
    println!("\n{}", report::fig9_report(&f));

    // --- validation: real threaded runs at small P on this box ---
    println!("\nvalidation: threaded wallclock at small P (af analogue):");
    let (_, prep) = suite.iter().find(|(m, _)| m.name == "af_5_k101_like").unwrap();
    let x: Vec<f64> = (0..prep.n).map(|i| (i as f64 * 0.3).sin()).collect();
    let mut y = vec![0.0; prep.n];
    let t_serial = time_fn(2, 5, || {
        sss_spmv(&prep.sss, &x, &mut y);
        std::hint::black_box(&y);
    });
    println!("  serial Alg.1: {:.3e}s", t_serial.min);
    for p in [1usize, 2, 4] {
        let plan = Arc::new(Pars3Plan::new(prep.split.clone(), p)?);
        let t = time_fn(1, 3, || {
            let (out, _) = plan.execute_threaded(&x);
            std::hint::black_box(&out);
        });
        println!(
            "  pars3 threaded P={p}: {:.3e}s  (1-core box: expect overhead, not speedup)",
            t.min
        );
    }
    println!("\nNote: this machine has 1 physical core; absolute threaded speedup is");
    println!("measured on the cost replay calibrated above (DESIGN.md §2 substitution).");
    Ok(())
}
