//! Quickstart: the whole PARS3 pipeline on a small matrix in ~40 lines.
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use pars3::coordinator::{Backend, Config, Coordinator};
use pars3::sparse::{gen, skew};
use pars3::util::SmallRng;

fn main() -> pars3::Result<()> {
    // 1. A small shifted skew-symmetric system  A = alpha*I + S
    //    (banded FEM-like pattern, scrambled so RCM has work to do).
    let n = 2000;
    let alpha = 2.0;
    let mut rng = SmallRng::seed_from_u64(42);
    let edges = gen::random_banded_pattern(n, 4, 0.5, &mut rng);
    let edges = gen::scramble(&edges, n, &mut rng);
    let coo = skew::coo_from_pattern(n, &edges, alpha, &mut rng);
    println!("matrix: n={n}, nnz={} (full COO)", coo.nnz());

    // 2. One-time preprocessing: reorder -> band -> 3-way split.
    let mut coord = Coordinator::new(Config::default());
    let prep = coord.prepare("quickstart", &coo)?;
    println!(
        "{}: bandwidth {} -> {}  | split: middle={} outer={} (split_bw={})",
        prep.plan.reorder.strategy,
        prep.bw_before,
        prep.reordered_bw,
        prep.split.nnz_middle(),
        prep.split.nnz_outer(),
        prep.split.split_bw
    );

    // 3. Conflict pre-identification at 8 ranks (Fig. 2).
    let cm = prep.conflicts(8);
    println!(
        "conflicts at P=8: {} of {} stored entries ({:.2}%)",
        cm.total_conflicts(),
        prep.nnz_lower,
        100.0 * cm.total_conflicts() as f64 / prep.nnz_lower as f64
    );

    // 4. The same multiply on three backends.
    let x: Vec<f64> = (0..n).map(|i| (i as f64 * 0.01).sin()).collect();
    let y_serial = coord.spmv(&prep, &x, Backend::Serial)?;
    let y_pars3 = coord.spmv(&prep, &x, Backend::Pars3 { p: 8 })?;
    let max_err = y_serial
        .iter()
        .zip(&y_pars3)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("pars3(P=8) vs serial: max |dy| = {max_err:.3e}");

    match coord.spmv(&prep, &x, Backend::Pjrt) {
        Ok(y_pjrt) => {
            let err = y_serial
                .iter()
                .zip(&y_pjrt)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f64, f64::max);
            println!("pjrt (AOT Pallas band kernel) vs serial: max |dy| = {err:.3e} (f32 path)");
        }
        Err(e) => println!("pjrt backend skipped: {e:#} (run `make artifacts`)"),
    }
    println!("quickstart OK");
    Ok(())
}
