//! §Perf probe: PJRT hot-loop variants, warm (compile amortized).
//! Compares: (a) single-step artifact with per-call band literal
//! (pre-optimization), (b) single-step with hoisted band literal,
//! (c) 8-iteration chunk with hoisted band (production path).
use pars3::runtime::{Manifest, PjrtRuntime};
use pars3::util::SmallRng;

fn main() -> pars3::Result<()> {
    let mut rt = PjrtRuntime::new(Manifest::load("artifacts")?)?;
    let (n, beta) = (1024usize, 16usize);
    let mut rng = SmallRng::seed_from_u64(1);
    let mut lo: Vec<f32> = (0..beta * n).map(|_| rng.gen_range_f64(-0.1, 0.1) as f32).collect();
    for d in 0..beta {
        for j in n - d - 1..n {
            lo[d * n + j] = 0.0; // band tail padding invariant
        }
    }
    let r0: Vec<f32> = (0..n).map(|_| rng.gen_range_f64(-1.0, 1.0) as f32).collect();
    let a = [2.0f32];
    let iters = 64usize;

    // (a) step artifact, per-call literals (old execute_f32 path)
    let step = rt.load("mrs_step_n1024_b16")?;
    let mut x = vec![0.0f32; n];
    let mut r = r0.clone();
    let _ = step.execute_f32(&[&lo, &x, &r, &a])?; // warmup/compile
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let out = step.execute_f32(&[&lo, &x, &r, &a])?;
        x = out[0].clone();
        r = out[1].clone();
    }
    let ta = t0.elapsed().as_secs_f64();
    let xa_final = x.clone();

    // (b) step artifact, hoisted band literal
    let lo_lit = step.literal_for(0, &lo)?;
    let a_lit = step.literal_for(3, &a)?;
    let mut x = vec![0.0f32; n];
    let mut r = r0.clone();
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let x_lit = step.literal_for(1, &x)?;
        let r_lit = step.literal_for(2, &r)?;
        let out = step.execute_literals(&[&lo_lit, &x_lit, &r_lit, &a_lit])?;
        x = out[0].clone();
        r = out[1].clone();
    }
    let tb = t0.elapsed().as_secs_f64();

    // (c) chunk artifact (8 fused iters), hoisted band literal
    let chunk = rt.load("mrs_chunk_n1024_b16")?;
    let lo_lit = chunk.literal_for(0, &lo)?;
    let a_lit = chunk.literal_for(3, &a)?;
    let warm = vec![0.0f32; n];
    let _ = chunk.execute_literals(&[&lo_lit, &chunk.literal_for(1, &warm)?, &chunk.literal_for(2, &r0)?, &a_lit])?;
    let mut x2 = vec![0.0f32; n];
    let mut r2 = r0.clone();
    let t0 = std::time::Instant::now();
    for _ in 0..iters / 8 {
        let x_lit = chunk.literal_for(1, &x2)?;
        let r_lit = chunk.literal_for(2, &r2)?;
        let out = chunk.execute_literals(&[&lo_lit, &x_lit, &r_lit, &a_lit])?;
        x2 = out[0].clone();
        r2 = out[1].clone();
    }
    let tc = t0.elapsed().as_secs_f64();

    println!("per-iteration (warm, n=1024 beta=16, {iters} iters):");
    println!("  (a) step + per-call literals : {:8.1} us", ta / iters as f64 * 1e6);
    println!("  (b) step + hoisted band      : {:8.1} us  ({:.2}x)", tb / iters as f64 * 1e6, ta / tb);
    println!("  (c) 8-iter chunk + hoisted   : {:8.1} us  ({:.2}x)", tc / iters as f64 * 1e6, ta / tc);
    let xa = xa_final;
    let err_ab = xa.iter().zip(&x).map(|(p, q)| (p - q).abs()).fold(0.0f32, f32::max);
    let err_bc = x.iter().zip(&x2).map(|(p, q)| (p - q).abs()).fold(0.0f32, f32::max);
    let nx = x.iter().map(|v| v * v).sum::<f32>().sqrt();
    println!("  ||x|| = {nx:.3}  max|x_a-x_b| = {err_ab:.2e}  max|x_b-x_c| = {err_bc:.2e}");
    Ok(())
}
