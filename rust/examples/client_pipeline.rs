//! The typed, handle-based client API end to end: a sharded service,
//! pipelined tickets, overlap of `prepare` with serving, generational
//! handles, and typed errors.
//!
//! ```text
//! cargo run --release --example client_pipeline
//! ```

use pars3::coordinator::{Backend, Config, Pars3Error, Service};
use pars3::sparse::{gen, skew};
use pars3::util::SmallRng;

fn main() -> pars3::Result<()> {
    // 1. A service with two shard workers, each owning a Coordinator
    //    and its kernel cache; clients are cheap clones over the pool.
    let cfg = Config { shards: 2, ..Config::default() };
    let svc = Service::start(cfg);
    let client = svc.client();

    // 2. Two shifted skew-symmetric systems.
    let mut rng = SmallRng::seed_from_u64(7);
    let make = |n: usize, rng: &mut SmallRng| {
        let edges = gen::random_banded_pattern(n, 4, 0.5, rng);
        skew::coo_from_pattern(n, &edges, 2.0, rng)
    };
    let coo_a = make(1500, &mut rng);
    let coo_b = make(1200, &mut rng);

    // 3. Register matrix A, then OVERLAP: while B's (expensive) RCM +
    //    split preprocessing runs on its shard, A already serves
    //    pipelined multiplies on the other.
    let ha = client.prepare("a", coo_a).wait()?;
    let prep_b = client.prepare("b", coo_b); // in flight on the other shard
    let tickets: Vec<_> = (0..4)
        .map(|c| {
            let x: Vec<f64> = (0..1500).map(|i| ((i + c) as f64 * 0.01).sin()).collect();
            client.spmv(&ha, x, Backend::Pars3 { p: 4 })
        })
        .collect();
    for (c, t) in tickets.into_iter().enumerate() {
        let y = t.wait()?;
        let norm: f64 = y.iter().map(|v| v * v).sum::<f64>().sqrt();
        println!("request {c} against 'a': ||y|| = {norm:.6e}");
    }
    let hb = prep_b.wait()?;
    println!(
        "'a' on shard {} and 'b' on shard {} were prepared/served concurrently",
        ha.shard(),
        hb.shard()
    );

    // 4. The kernel cache amortizes across the pipelined stream.
    let stats = client.cache_stats(ha.shard()).wait()?;
    println!("shard {}: {} kernel build(s) for 4 requests", stats.shard, stats.built);

    // 5. Generational handles: re-preparing under `ha` bumps the
    //    generation, so the old handle fails loudly and typed.
    let ha2 = client.prepare_replace(&ha, "a", make(1500, &mut rng)).wait()?;
    let x = vec![1.0; 1500];
    match client.spmv(&ha, x.clone(), Backend::Serial).wait() {
        Err(Pars3Error::StaleHandle { held, current, .. }) => {
            println!("old handle rejected: generation {held} vs current {current}")
        }
        other => anyhow::bail!("expected StaleHandle, got {:?}", other.map(|y| y.len())),
    }
    let y = client.spmv(&ha2, x, Backend::Serial).wait()?;
    println!("fresh handle (generation {}) works: y[0] = {:.3}", ha2.generation(), y[0]);

    // 6. Typed dimension errors instead of formatted strings.
    match client.spmv(&ha2, vec![0.0; 3], Backend::Serial).wait() {
        Err(Pars3Error::DimensionMismatch { expected, got }) => {
            println!("dimension mismatch caught: expected {expected}, got {got}")
        }
        other => anyhow::bail!("expected DimensionMismatch, got {:?}", other.map(|y| y.len())),
    }

    // 7. Release a matrix when done: kernels evicted, memory dropped,
    //    and the slot is reused by the next prepare.
    client.release(&hb).wait()?;
    match client.spmv(&hb, vec![0.0; 1200], Backend::Serial).wait() {
        Err(Pars3Error::StaleHandle { .. }) => println!("released handle is stale, as it must be"),
        other => {
            anyhow::bail!("expected StaleHandle after release, got {:?}", other.map(|y| y.len()))
        }
    }

    svc.shutdown();
    println!("service stopped.");
    Ok(())
}
