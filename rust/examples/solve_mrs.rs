//! End-to-end validation driver (DESIGN.md §5 E2E): solve a real small
//! workload — a 2-D convection operator (naturally skew-symmetric after
//! central differencing) shifted by alpha — with the MRS iterative
//! solver, through all three execution paths:
//!
//!   * serial Alg. 1 (paper baseline),
//!   * PARS3 parallel kernel,
//!   * the AOT JAX+Pallas artifact via PJRT (`mrs_step`, one execution
//!     per solver iteration — Python never runs).
//!
//! Logs the residual curve and cross-checks the three solutions.
//! Results are recorded in EXPERIMENTS.md.
//!
//! ```text
//! make artifacts && cargo run --release --example solve_mrs
//! ```

use pars3::coordinator::{Backend, Config, Coordinator};
use pars3::solver::mrs::MrsOptions;
use pars3::sparse::Coo;
use pars3::util::SmallRng;

/// Central-difference convection operator on an nx x ny grid:
/// u_x + u_y with periodic-free boundaries gives S[i][j] = -S[j][i]
/// on grid neighbours — a *naturally* skew-symmetric matrix
/// (the Navier-Stokes connection the paper cites).
fn convection2d(nx: usize, ny: usize, alpha: f64, vx: f64, vy: f64) -> Coo {
    let n = nx * ny;
    let id = |x: usize, y: usize| (y * nx + x) as u32;
    let mut c = Coo::new(n);
    for i in 0..n as u32 {
        c.push(i, i, alpha);
    }
    for y in 0..ny {
        for x in 0..nx {
            if x + 1 < nx {
                // u_x central difference: +v/2 forward, -v/2 backward
                c.push(id(x, y), id(x + 1, y), vx / 2.0);
                c.push(id(x + 1, y), id(x, y), -vx / 2.0);
            }
            if y + 1 < ny {
                c.push(id(x, y), id(x, y + 1), vy / 2.0);
                c.push(id(x, y + 1), id(x, y), -vy / 2.0);
            }
        }
    }
    c
}

fn rel_res(hist: &[f64]) -> f64 {
    (hist.last().unwrap() / hist[0]).sqrt()
}

fn main() -> pars3::Result<()> {
    let (nx, ny) = (32, 30); // n = 960 <= 1024 artifact config
    let alpha = 1.5;
    let coo = convection2d(nx, ny, alpha, 1.0, 0.7);
    println!("2-D convection system: {}x{} grid, n={}, nnz={}", nx, ny, nx * ny, coo.nnz());

    let mut coord = Coordinator::new(Config::default());
    let prep = coord.prepare("convection2d", &coo)?;
    println!(
        "preprocessing: bandwidth {} -> {} ({}), middle={} outer={}",
        prep.bw_before,
        prep.reordered_bw,
        prep.plan.reorder.strategy,
        prep.split.nnz_middle(),
        prep.split.nnz_outer()
    );

    let mut rng = SmallRng::seed_from_u64(7);
    let b: Vec<f64> = (0..prep.n).map(|_| rng.gen_range_f64(-1.0, 1.0)).collect();
    let opts = MrsOptions { alpha, max_iters: 400, tol: 1e-8 };

    // --- serial baseline ---
    let t0 = std::time::Instant::now();
    let rs = coord.solve(&prep, &b, &opts, Backend::Serial)?;
    let ts = t0.elapsed().as_secs_f64();
    println!(
        "\nserial   : converged={} iters={:3} rel_res={:.3e}  {ts:.3}s",
        rs.converged,
        rs.iters,
        rel_res(&rs.history)
    );

    // --- PARS3 ---
    let t0 = std::time::Instant::now();
    let rp = coord.solve(&prep, &b, &opts, Backend::Pars3 { p: 8 })?;
    let tp = t0.elapsed().as_secs_f64();
    println!(
        "pars3 P=8: converged={} iters={:3} rel_res={:.3e}  {tp:.3}s",
        rp.converged,
        rp.iters,
        rel_res(&rp.history)
    );

    // --- PJRT (AOT Pallas) ---
    let t0 = std::time::Instant::now();
    let rj = coord.solve(&prep, &b, &opts, Backend::Pjrt)?;
    let t_cold = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let rj = coord.solve(&prep, &b, &opts, Backend::Pjrt)?;
    let t_warm = t0.elapsed().as_secs_f64();
    println!(
        "pjrt     : converged={} iters={:3} rel_res={:.3e}  cold {t_cold:.3}s / warm {t_warm:.4}s \
         ({:.1}us/iter warm; XLA compile amortized)",
        rj.converged,
        rj.iters,
        rel_res(&rj.history),
        t_warm / rj.iters.max(1) as f64 * 1e6
    );

    // residual curve (every 25 iters)
    println!("\nresidual curve (serial):");
    for (k, rr) in rs.history.iter().enumerate().step_by(25) {
        println!("  iter {k:4}: ||r||/||b|| = {:.6e}", (rr / rs.history[0]).sqrt());
    }

    // cross-checks
    let d_sp = rs.x.iter().zip(&rp.x).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
    let d_sj = rs.x.iter().zip(&rj.x).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
    println!("\nmax |x_serial - x_pars3| = {d_sp:.3e} (f64 paths)");
    println!("max |x_serial - x_pjrt | = {d_sj:.3e} (f32 artifact path)");

    // verify against a fresh multiply
    let ax = coord.spmv(&prep, &rs.x, Backend::Serial)?;
    let resid: f64 = ax.iter().zip(&b).map(|(a, c)| (a - c) * (a - c)).sum::<f64>().sqrt();
    let bn: f64 = b.iter().map(|v| v * v).sum::<f64>().sqrt();
    println!("independent check: ||A x - b|| / ||b|| = {:.3e}", resid / bn);

    assert!(rs.converged && rp.converged && rj.converged);
    assert!(d_sp < 1e-6 && d_sj < 1e-2);
    println!("\nsolve_mrs E2E OK");
    Ok(())
}
