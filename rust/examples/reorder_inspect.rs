//! Inspect RCM reordering and the 3-way band split on the benchmark
//! suite — regenerates the structural content of Figs. 1, 4, 5, 6, 7, 8
//! (bandwidth reduction, split sizes/densities, band profiles) plus an
//! ASCII spy plot of a matrix before/after RCM.
//!
//! ```text
//! cargo run --release --example reorder_inspect [-- scale]
//! ```

use pars3::coordinator::Config;
use pars3::report;
use pars3::sparse::band::BandProfile;
use pars3::sparse::Sss;

/// Tiny ASCII spy plot of the lower-triangle pattern (Figs. 1/4/8).
fn spy(s: &Sss, cells: usize) -> String {
    let n = s.n.max(1);
    let mut grid = vec![vec![false; cells]; cells];
    let at = |i: usize| (i * cells / n).min(cells - 1);
    for i in 0..s.n {
        grid[at(i)][at(i)] = true; // diagonal
        for (j, _) in s.row(i) {
            grid[at(i)][at(j as usize)] = true;
        }
    }
    let mut out = String::new();
    for row in grid {
        for c in row {
            out.push(if c { '*' } else { '.' });
        }
        out.push('\n');
    }
    out
}

fn main() -> pars3::Result<()> {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let cfg = Config { scale, ..Config::default() };
    let suite = report::prepared_suite(&cfg)?;

    println!("{}", report::table1(&suite));
    println!("{}", report::rcm_report(&suite));
    println!("{}", report::splits_report(&suite, &[1, 3, 8, 16]));
    println!("{}", report::conflict_report(&suite, &cfg.ranks));

    // spy plot of the boneS10 analogue after RCM (Fig. 4)
    let (m, prep) = suite.iter().find(|(m, _)| m.name == "boneS10_like").unwrap();
    println!("### spy plot: {} after RCM (lower triangle, {}x{} cells)\n", m.name, 40, 40);
    println!("{}", spy(&prep.sss, 40));

    let prof = BandProfile::of(&prep.sss);
    println!(
        "profile: bandwidth={} envelope={} band_density={:.4} mean|i-j|={:.1}",
        prof.bandwidth,
        prof.profile,
        prof.band_density(),
        prof.mean_distance()
    );
    Ok(())
}
