//! PARS3 vs the conflict-free graph-coloring SSpMV of Elafrou et al. [3]
//! (the §4.1 comparison): phases, conflict counts, and modeled speedups.
//!
//! ```text
//! cargo run --release --example coloring_compare [-- scale]
//! ```

use pars3::coordinator::Config;
use pars3::graph::coloring::color_rows;
use pars3::kernel::coloring_spmv::ColoringPlan;
use pars3::kernel::serial_sss::sss_spmv;
use pars3::mpisim::CostModel;
use pars3::report;
use std::sync::Arc;

fn main() -> pars3::Result<()> {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(1.0);
    let cfg = Config { scale, ..Config::default() };
    let suite = report::prepared_suite(&cfg)?;
    let biggest = suite.iter().max_by_key(|(_, p)| p.nnz_lower).unwrap();
    let model = CostModel::calibrate(&biggest.1.sss, 5);

    println!("{}", report::coloring_compare(&suite, &cfg.ranks, &model));

    // numerics check: the phased executor returns the same y
    let (_, prep) = &suite[0];
    let x: Vec<f64> = (0..prep.n).map(|i| (i as f64 * 0.17).cos()).collect();
    let mut want = vec![0.0; prep.n];
    sss_spmv(&prep.sss, &x, &mut want);
    let coloring = color_rows(&prep.sss);
    let plan = Arc::new(ColoringPlan::new(prep.sss.clone(), 4)?);
    let got = plan.execute_threaded(&x);
    let err = got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
    println!(
        "numerics check ({}): {} phases, threaded phased executor max |dy| = {err:.3e}",
        suite[0].0.name, coloring.num_colors
    );
    assert!(err < 1e-9);
    Ok(())
}
