//! The wire protocol end to end: a TCP/UDS server over the sharded
//! service, a `RemoteClient` with the in-process client's typed
//! surface, pipelined requests over one socket, and numerics identical
//! to the local path.
//!
//! ```text
//! cargo run --release --example remote_client              # self-served UDS
//! cargo run --release --example remote_client tcp://127.0.0.1:7313
//! cargo run --release --example remote_client uds:/tmp/pars3.sock
//! ```
//!
//! With an address argument it connects to an already-running
//! `pars3 serve --listen ...`; without one it binds its own
//! Unix-domain server first (so the example is self-contained).

use pars3::coordinator::{Backend, ClientApi, Config, Coordinator};
use pars3::net::{Listen, RemoteClient, Server};
use pars3::sparse::gen;

fn main() -> pars3::Result<()> {
    // 1. Find or start a server.
    let (addr, own_server) = match std::env::args().nth(1) {
        Some(spec) => (spec.parse::<Listen>()?, None),
        None => {
            let dir = std::env::temp_dir()
                .join(format!("pars3-remote-example-{}", std::process::id()));
            std::fs::create_dir_all(&dir)?;
            let listen = Listen::Uds(dir.join("pars3.sock"));
            let server = Server::bind(&listen, Config { shards: 2, ..Config::default() })?;
            println!("self-serving on {listen}");
            (listen, Some((server, dir)))
        }
    };

    // 2. Connect and register a matrix. The COO crosses the wire as raw
    //    little-endian bytes; RCM + split preprocessing runs server-side.
    let client = RemoteClient::connect(&addr)?;
    let n = 1500;
    let coo = gen::small_test_matrix(n, 42, 2.0);
    let handle = client.prepare("remote", coo.clone()).wait()?;
    let info = client.describe(&handle).wait()?;
    println!(
        "prepared '{}' remotely: n={} nnz_lower={} bw {} -> {}",
        info.name, info.n, info.nnz_lower, info.bw_before, info.reordered_bw
    );

    // 3. Pipelined burst: every request is on the wire before the first
    //    wait — the same overlap the in-process client gets from its
    //    shard queues, here across one socket.
    let burst = 6;
    let inputs: Vec<Vec<f64>> = (0..burst)
        .map(|c| (0..n).map(|i| ((i + c) as f64 * 0.01).sin()).collect())
        .collect();
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| client.spmv(&handle, x.clone(), Backend::Pars3 { p: 4 }))
        .collect();
    println!("{burst} requests submitted before the first response was read");

    // 4. The remote results must equal the local pipeline bit-for-bit
    //    modulo nothing: the wire moves raw f64 bytes, and the server
    //    runs the same kernels on the same matrix.
    let mut coord = Coordinator::new(Config::default());
    let prep = coord.prepare("local", &coo)?;
    let mut worst: f64 = 0.0;
    for (x, t) in inputs.iter().zip(tickets) {
        let remote = t.wait()?;
        let local = coord.spmv(&prep, x, Backend::Pars3 { p: 4 })?;
        let diff =
            remote.iter().zip(&local).map(|(a, b)| (a - b).abs()).fold(0.0f64, f64::max);
        worst = worst.max(diff);
    }
    anyhow::ensure!(worst <= 1e-12, "remote diverged from local: {worst:.3e}");
    println!("remote == local across the burst: max |delta| = {worst:.3e} OK");

    // 5. Typed errors survive the wire as variants, not strings.
    client.release(&handle).wait()?;
    match client.spmv(&handle, vec![0.0; n], Backend::Serial).wait() {
        Err(pars3::coordinator::Pars3Error::StaleHandle { .. }) => {
            println!("released handle rejected with the typed StaleHandle, over TCP/UDS")
        }
        other => anyhow::bail!("expected StaleHandle, got {:?}", other.map(|y| y.len())),
    }

    // 6. If we started the server, stop it gracefully over the wire.
    if let Some((server, dir)) = own_server {
        client.stop().wait()?;
        server.join();
        let _ = std::fs::remove_dir_all(&dir);
        println!("server stopped over the wire.");
    }
    println!("remote session ok");
    Ok(())
}
